"""CLI for the invariant lint: ``python -m repro.analysis [paths...]``.

With no arguments, lints the installed ``repro`` warehouse sources (the
package directory itself).  Exits 0 when clean, 1 when any unsuppressed
finding remains, 2 on usage/parse errors.  This is the CI lint gate.
"""
from __future__ import annotations

import argparse
import os
import sys

from .lint import CODES, lint_paths


def _default_paths():
    import repro

    # repro is a namespace package: use __path__, not __file__
    return [os.path.abspath(p) for p in repro.__path__]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific invariant lint (REP001..REP007)",
    )
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint "
                         "(default: the repro package)")
    ap.add_argument("--codes", action="store_true",
                    help="list checker codes and exit")
    args = ap.parse_args(argv)

    if args.codes:
        for code, desc in sorted(CODES.items()):
            print(f"{code}  {desc}")
        return 0

    paths = args.paths or _default_paths()
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2
    try:
        findings = lint_paths(paths)
    except SyntaxError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    for f in findings:
        print(f)
    n = len(findings)
    roots = ", ".join(paths)
    print(f"repro.analysis: {n} finding(s) in {roots}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
