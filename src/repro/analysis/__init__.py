"""Warehouse correctness toolkit: invariant lint, lockdep, plan validator,
schema-flow checker.

Four analyzers, one entry point (``python -m repro.analysis``):

* :mod:`repro.analysis.lint` — AST lint over the warehouse sources
  enforcing repo-specific invariants REP001..REP006 (declared config keys,
  cancellable reader loops, no new full-materialization sites, lock/
  condition hygiene, validated live-DAG mutation, schema-derived operator
  output columns);
* :mod:`repro.analysis.lockdep` — runtime lock-order sanitizer behind the
  ``REPRO_LOCKDEP`` env var; lock factories used across the runtime;
* :mod:`repro.analysis.plan_validator` — structural checks on every
  compiled task DAG behind ``debug.validate_plans`` /
  ``REPRO_VALIDATE_PLANS``;
* :mod:`repro.analysis.schema_check` — static schema-flow verification
  (rules SCH001..SCH006) over the typed contract ``repro.core.schema``
  attaches to plans and DAGs, run by ``check_dag`` after the structural
  pass (the runtime counterpart — per-morsel exchange conformance — sits
  behind ``REPRO_CHECK_BATCHES`` / ``debug.check_batches``).
"""
from .lint import CODES, Finding, lint_file, lint_paths, lint_source
from .lockdep import (LockOrderError, TrackedCondition, TrackedLock,
                      TrackedRLock, make_condition, make_lock, make_rlock)
from .plan_validator import (PlanValidationError, check_dag,
                             maybe_validate_dag, validate_dag)
from .schema_check import RULES, validate_dag_schemas, validate_plan_schema

__all__ = [
    "CODES", "Finding", "lint_file", "lint_paths", "lint_source",
    "LockOrderError", "TrackedCondition", "TrackedLock", "TrackedRLock",
    "make_condition", "make_lock", "make_rlock",
    "PlanValidationError", "check_dag", "maybe_validate_dag", "validate_dag",
    "RULES", "validate_dag_schemas", "validate_plan_schema",
]
