"""Warehouse correctness toolkit: invariant lint, lockdep, plan validator.

Three analyzers, one entry point (``python -m repro.analysis``):

* :mod:`repro.analysis.lint` — AST lint over the warehouse sources
  enforcing repo-specific invariants REP001..REP004 (declared config keys,
  cancellable reader loops, no new full-materialization sites, lock/
  condition hygiene);
* :mod:`repro.analysis.lockdep` — runtime lock-order sanitizer behind the
  ``REPRO_LOCKDEP`` env var; lock factories used across the runtime;
* :mod:`repro.analysis.plan_validator` — structural checks on every
  compiled task DAG behind ``debug.validate_plans`` /
  ``REPRO_VALIDATE_PLANS``.
"""
from .lint import CODES, Finding, lint_file, lint_paths, lint_source
from .lockdep import (LockOrderError, TrackedCondition, TrackedLock,
                      TrackedRLock, make_condition, make_lock, make_rlock)
from .plan_validator import (PlanValidationError, check_dag,
                             maybe_validate_dag, validate_dag)

__all__ = [
    "CODES", "Finding", "lint_file", "lint_paths", "lint_source",
    "LockOrderError", "TrackedCondition", "TrackedLock", "TrackedRLock",
    "make_condition", "make_lock", "make_rlock",
    "PlanValidationError", "check_dag", "maybe_validate_dag", "validate_dag",
]
