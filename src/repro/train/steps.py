"""train_step / serve_step / input_specs for every (arch × shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for all step
inputs — weak-type-correct and shardable, with zero device allocation — which
is what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..models import model as M
from ..models.layers import NOSHARD, ShardCtx
from .optimizer import AdamWState, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy; logits (B,S,V) any float dtype, labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, ctx: ShardCtx = NOSHARD,
                    microbatches: int = 1, remat: bool = True, lr: float = 3e-4):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With microbatches > 1 the global batch is split and gradients accumulate
    in f32 across a lax.scan (gradient accumulation — keeps the logits
    buffer at 1/M size, the standard large-batch memory trick)."""

    def loss_fn(params, inputs, labels):
        logits = M.forward(params, cfg, inputs, ctx, remat=remat)
        loss = softmax_xent(logits, labels)
        return loss

    def train_step(params, opt_state: AdamWState, batch: Dict):
        inputs, labels = batch["inputs"], batch["labels"]
        if microbatches > 1:
            B = inputs.shape[0]
            mb = B // microbatches
            minputs = inputs.reshape((microbatches, mb) + inputs.shape[1:])
            mlabels = labels.reshape((microbatches, mb) + labels.shape[1:])

            def acc(carry, xs):
                gsum, lsum = carry
                mi, ml = xs
                l, g = jax.value_and_grad(loss_fn)(params, mi, ml)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, 0.0), (minputs, mlabels))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, inputs, labels)
        new_params, new_opt, gnorm = adamw_update(grads, opt_state, params, lr=lr)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


# ---------------------------------------------------------------------------
# serve (decode)
# ---------------------------------------------------------------------------
def make_serve_step(cfg: ModelConfig, ctx: ShardCtx = NOSHARD):
    """serve_step(params, cache, inputs, pos) -> (next_token, cache).

    One new token against a KV cache of the shape's seq_len."""

    def serve_step(params, cache, inputs, pos):
        logits, new_cache = M.decode_step(params, cache, inputs, pos, cfg, ctx)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx = NOSHARD):
    """Forward-only step for prefill shapes (logits for the last position)."""

    def prefill_step(params, inputs):
        logits = M.forward(params, cfg, inputs, ctx, remat=False)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; also used to build real smoke batches)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.embedding_stub:
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        else:
            inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return {
            "batch": {
                "inputs": inputs,
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        }
    if shape.kind == "prefill":
        if cfg.embedding_stub:
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        else:
            inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return {"inputs": inputs}
    # decode: one new token with a cache of seq_len
    if cfg.embedding_stub:
        inputs = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.ShapeDtypeStruct((B,), jnp.int32)
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    return {
        "inputs": inputs,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


def materialize_batch(cfg: ModelConfig, shape: ShapeConfig, key) -> Dict:
    """Concrete random inputs matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)

    def mk(s):
        if s.dtype == jnp.int32 and s.shape and s.shape[-1] != cfg.d_model:
            return jax.random.randint(key, s.shape, 0, cfg.vocab_size, jnp.int32)
        if s.dtype == jnp.int32:
            return jnp.zeros(s.shape, jnp.int32)
        return jax.random.normal(key, s.shape, jnp.float32).astype(s.dtype) * 0.02

    return jax.tree.map(mk, specs)
