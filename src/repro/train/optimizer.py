"""AdamW optimizer, sharded: moment tensors inherit parameter shardings."""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Dict
    nu: Dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_state_axes(axes):
    """Moments shard exactly like their parameters."""
    return AdamWState(step=(None,), mu=axes, nu=axes)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Tuple[Dict, AdamWState, jnp.ndarray]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    corr1 = 1.0 - b1 ** t
    corr2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / corr1
        vhat = v / corr2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([x[0] for x in new])
    new_m = treedef.unflatten([x[1] for x in new])
    new_v = treedef.unflatten([x[2] for x in new])
    return new_p, AdamWState(step, new_m, new_v), gnorm
