"""Mixture-of-Experts FFN with capacity-based sorted dispatch.

TPU-native design: no ragged compute.  Tokens pick top-k experts; each
(token, slot) is assigned a position inside its expert's fixed-capacity
buffer via a cumulative-sum scheme; a scatter builds the (E, C, D) dispatch
buffer; expert FFNs run as one batched einsum (MXU-friendly); a gather +
weighted combine restores token order.  Compute scales with E*C ≈ T*k —
i.e. with *active* parameters, matching the 6·N_active·D roofline model.

Sharding: expert-stacked weights (E, D, F) shard E over 'model' when it
divides (olmoe: 64/16); otherwise the per-expert matrices shard over
('data','model') (grok: 8 experts × 314B params ⇒ fully sharded weights).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import ShardCtx, trunc_normal


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": trunc_normal(ks[0], (d, e), 1.0, jnp.float32),
        "w_gate": trunc_normal(ks[1], (e, d, f), 1.0, dtype),
        "w_up": trunc_normal(ks[2], (e, d, f), 1.0, dtype),
        "w_down": trunc_normal(ks[3], (e, f, d), 1.0, dtype),
    }


def moe_axes(cfg: ModelConfig):
    # preferred: experts on 'model'; the resolver drops axes that don't
    # divide, falling back to the later dims' ('data','model') spec.
    return {
        "router": (None, None),
        "w_gate": ("model", "data", None) if _experts_shardable(cfg) else (None, "data", "model"),
        "w_up": ("model", "data", None) if _experts_shardable(cfg) else (None, "data", "model"),
        "w_down": ("model", None, "data") if _experts_shardable(cfg) else (None, "model", "data"),
    }


def _experts_shardable(cfg: ModelConfig) -> bool:
    return cfg.moe is not None and cfg.moe.num_experts >= 16


def moe_ffn(p, x: jnp.ndarray, cfg: ModelConfig, ctx: ShardCtx) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    k, E = m.top_k, m.num_experts
    xf = x.reshape(T, D)

    logits = xf.astype(jnp.float32) @ p["router"]  # (T, E)
    topv, topi = jax.lax.top_k(logits, k)  # (T, k)
    gates = jax.nn.softmax(topv, axis=-1)  # (T, k)

    C = int(np.ceil(T * k / E * m.capacity_factor))
    C = max(int(np.ceil(C / 8)) * 8, 8)  # pad capacity to a lane multiple

    # position of each (token, slot) inside its expert's buffer
    flat_e = topi.reshape(T * k)  # expert id per slot
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < C  # overflowing tokens are dropped (capacity routing)

    token_of = jnp.repeat(jnp.arange(T), k)
    buf_idx = jnp.where(keep, flat_e * C + pos, E * C)  # E*C = drop slot
    dispatch = jnp.zeros((E * C + 1, D), dtype=x.dtype)
    dispatch = dispatch.at[buf_idx].set(xf[token_of])
    dispatch = dispatch[: E * C].reshape(E, C, D)
    # EP when the expert count divides the model axis; otherwise shard the
    # capacity/feature dims so GSPMD never replicates the (E, C, D) buffer
    # (grok: E=8 < model=16 — see EXPERIMENTS.md §Perf iteration 2)
    if _experts_shardable(cfg):
        disp_spec, h_spec = (ctx.tp, ctx.dp_spec, None), (ctx.tp, ctx.dp_spec, None)
    else:
        disp_spec, h_spec = (None, ctx.dp_spec, ctx.tp), (None, ctx.dp_spec, ctx.tp)
    dispatch = ctx.constrain(dispatch, disp_spec)

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    gate_h = jnp.einsum("ecd,edf->ecf", dispatch, p["w_gate"])
    up_h = jnp.einsum("ecd,edf->ecf", dispatch, p["w_up"])
    h = act(gate_h) * up_h
    h = ctx.constrain(h, h_spec)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E, C, D)
    out_e = ctx.constrain(out_e, disp_spec)

    flat_out = out_e.reshape(E * C, D)
    slot_out = jnp.where(keep[:, None], flat_out[jnp.minimum(buf_idx, E * C - 1)], 0)
    weighted = slot_out * gates.reshape(T * k, 1).astype(slot_out.dtype)
    y = jax.ops.segment_sum(weighted, token_of, num_segments=T)
    y = ctx.constrain(y.reshape(B, S, D), (ctx.dp_spec, None, None))
    return y.astype(x.dtype)


def aux_load_balance_loss(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (used in training)."""
    m = cfg.moe
    T = x.shape[0] * x.shape[1]
    logits = x.reshape(T, -1).astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, topi = jax.lax.top_k(logits, m.top_k)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi, m.num_experts, dtype=jnp.float32).sum(1), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)
