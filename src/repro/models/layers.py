"""Core transformer layers: norms, RoPE, attention (GQA/MQA, qk-norm,
sliding window), gated MLPs — pure JAX, shard-constraint aware.

All functions take a `ShardCtx` that applies `with_sharding_constraint`s
only when a mesh is active (dry-run / production) and silently no-ops in
single-device smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Activation-sharding helper; axes=None disables constraints."""

    dp: Tuple[str, ...] = ()  # data-parallel mesh axes ('pod','data') / ('data',)
    tp: Optional[str] = None  # tensor-parallel axis ('model')
    axis_sizes: Optional[Dict[str, int]] = None

    def _fits(self, dim: int, axes) -> bool:
        if axes is None or self.axis_sizes is None:
            return True
        names = axes if isinstance(axes, tuple) else (axes,)
        total = 1
        for n in names:
            total *= self.axis_sizes.get(n, 1)
        return dim % total == 0

    def constrain(self, x: jnp.ndarray, spec: Tuple) -> jnp.ndarray:
        if self.axis_sizes is None:
            return x
        resolved = []
        for dim, axes in zip(x.shape, spec):
            resolved.append(axes if axes and self._fits(dim, axes) else None)
        try:
            return jax.lax.with_sharding_constraint(x, P(*resolved))
        except Exception:
            return x

    @property
    def dp_spec(self):
        return tuple(self.dp) if self.dp else None


NOSHARD = ShardCtx()


# ---------------------------------------------------------------------------
# initialization helpers: every parameter leaf is created through `mk`, which
# records its preferred sharding axes in a parallel tree (see model.py).
# ---------------------------------------------------------------------------
def trunc_normal(key, shape, scale, dtype=jnp.bfloat16):
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if plus_one:  # gemma-style (1 + w)
        w = 1.0 + w
    return (x * w).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": trunc_normal(ks[0], (d, hq * hd), 1.0, dtype),
        "wk": trunc_normal(ks[1], (d, hkv * hd), 1.0, dtype),
        "wv": trunc_normal(ks[2], (d, hkv * hd), 1.0, dtype),
        "wo": trunc_normal(ks[3], (hq * hd, d), 1.0, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def attention_axes(cfg: ModelConfig):
    a = {
        "wq": ("data", "model"),
        "wk": ("data", "model"),
        "wv": ("data", "model"),
        "wo": ("model", "data"),
    }
    if cfg.qk_norm:
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return a


def _qkv(p, x, cfg: ModelConfig, ctx: ShardCtx, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = ctx.constrain(q, (ctx.dp_spec, None, ctx.tp, None))
    k = ctx.constrain(k, (ctx.dp_spec, None, ctx.tp, None))
    v = ctx.constrain(v, (ctx.dp_spec, None, ctx.tp, None))
    return q, k, v


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    B, S, Hkv, hd = k.shape
    return jnp.repeat(k, n_rep, axis=2)


# Attention implementation selector (perf hillclimb, EXPERIMENTS.md §Perf):
#   'blocked' — baseline: q-chunked exact softmax; (q_block, S) score rows
#               materialize (HBM traffic grows with S)
#   'online'  — flash-style online softmax over VMEM-sized (q, k) tiles;
#               score tiles never leave the chip (jnp formulation of
#               kernels/flash_attention, so it lowers everywhere)
_ATTENTION_IMPL = "blocked"


def set_attention_impl(name: str) -> None:
    global _ATTENTION_IMPL
    assert name in ("blocked", "online")
    _ATTENTION_IMPL = name


def attention(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    sliding_window: Optional[int] = None,
    q_block: int = 1024,
) -> jnp.ndarray:
    """Causal self-attention for train/prefill."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, cfg, ctx, positions)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    q = q.transpose(0, 2, 1, 3)  # (B, H, S, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    if _ATTENTION_IMPL == "online":
        out = _attention_online(q, k, v, sliding_window, x.dtype)
    else:
        out = _attention_blocked(q, k, v, sliding_window, x.dtype, q_block)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.num_heads * hd)
    out = out @ p["wo"]
    return ctx.constrain(out, (ctx.dp_spec, None, None))


def _attention_blocked(q, k, v, sliding_window, dtype, q_block):
    B, H, S, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    nb = max(S // q_block, 1)
    if S % q_block != 0:
        nb, q_block = 1, S

    def chunk(carry, qb_idx):
        qs = qb_idx * q_block
        qi = jax.lax.dynamic_slice_in_dim(q, qs, q_block, axis=2)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        qpos = qs + jnp.arange(q_block)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = kpos <= qpos
        if sliding_window is not None:
            mask &= kpos > qpos - sliding_window
        scores = jnp.where(mask[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
        return carry, out.astype(dtype)

    _, chunks = jax.lax.scan(chunk, None, jnp.arange(nb))
    return chunks.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)


def _attention_online(q, k, v, sliding_window, dtype,
                      q_tile: int = 512, k_tile: int = 512):
    """Flash-style online softmax: running (max, denom, acc) per q tile,
    scanned over k tiles.  Every intermediate is a (q_tile, k_tile) or
    (q_tile, hd) tile — VMEM-resident on TPU."""
    B, H, S, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    q_tile = min(q_tile, S)
    k_tile = min(k_tile, S)
    if S % q_tile or S % k_tile:
        q_tile = k_tile = S
    nq, nk = S // q_tile, S // k_tile

    kt = k.astype(jnp.float32).reshape(B, H, nk, k_tile, hd)
    vt = v.astype(jnp.float32).reshape(B, H, nk, k_tile, hd)

    def q_chunk(carry, qi):
        qs = qi * q_tile
        qq = jax.lax.dynamic_slice_in_dim(q, qs, q_tile, axis=2).astype(jnp.float32)
        qpos = qs + jnp.arange(q_tile)[:, None]

        def k_chunk(state, ki):
            m_prev, l_prev, acc = state
            kk = kt[:, :, ki]  # (B, H, k_tile, hd)
            vv = vt[:, :, ki]
            s = jnp.einsum("bhqd,bhkd->bhqk", qq, kk) * scale
            kpos = ki * k_tile + jnp.arange(k_tile)[None, :]
            mask = kpos <= qpos
            if sliding_window is not None:
                mask &= kpos > qpos - sliding_window
            s = jnp.where(mask[None, None], s, -1e30)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p_ = jnp.exp(s - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_new = alpha * l_prev + jnp.sum(p_, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p_, vv)
            return (m_new, l_new, acc), None

        init = (
            jnp.full((B, H, q_tile, 1), -1e30, jnp.float32),
            jnp.zeros((B, H, q_tile, 1), jnp.float32),
            jnp.zeros((B, H, q_tile, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(k_chunk, init, jnp.arange(nk))
        return carry, (acc / jnp.maximum(l, 1e-30)).astype(dtype)

    _, chunks = jax.lax.scan(q_chunk, None, jnp.arange(nq))
    return chunks.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)


def decode_attention(
    p,
    x: jnp.ndarray,  # (B, 1, D)
    cache_k: jnp.ndarray,  # (B, S_max, Hkv, hd)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,  # scalar int32: current position
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    sliding_window: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode with KV cache; returns (out, new_k, new_v)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    S_max = cache_k.shape[1]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _qkv(p, x, cfg, ctx, positions)

    if sliding_window is not None and S_max == sliding_window:
        slot = jnp.mod(pos, sliding_window)  # ring buffer for local layers
    else:
        slot = pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1) \
        if False else jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))

    n_rep = cfg.num_heads // cfg.num_kv_heads
    kk = _repeat_kv(cache_k, n_rep)  # (B, S_max, Hq, hd)
    vv = _repeat_kv(cache_v, n_rep)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    kpos = jnp.arange(S_max)[None, None, None, :]
    if sliding_window is not None and S_max == sliding_window:
        valid = (kpos <= jnp.minimum(pos, S_max - 1)) | (pos >= S_max)
    else:
        valid = kpos <= pos
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", w, vv.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype)
    return out @ p["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": trunc_normal(ks[1], (d, f), 1.0, dtype),
        "w_down": trunc_normal(ks[2], (f, d), 1.0, dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = trunc_normal(ks[0], (d, f), 1.0, dtype)
    return p


def mlp_axes(cfg: ModelConfig):
    a = {"w_up": ("data", "model"), "w_down": ("model", "data")}
    if cfg.gated_mlp:
        a["w_gate"] = ("data", "model")
    return a


def mlp(p, x: jnp.ndarray, cfg: ModelConfig, ctx: ShardCtx) -> jnp.ndarray:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    up = x @ p["w_up"]
    if cfg.gated_mlp:
        h = act(x @ p["w_gate"]) * up
    else:
        h = act(up)
    h = ctx.constrain(h, (ctx.dp_spec, None, ctx.tp))
    out = h @ p["w_down"]
    return ctx.constrain(out, (ctx.dp_spec, None, None))
