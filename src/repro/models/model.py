"""Composable decoder-only LM covering all 10 assigned architectures.

Layer stacking uses ``jax.lax.scan`` over *pattern periods* (e.g. gemma3's
5-local+1-global period, zamba2's 5-ssm+1-shared period) so the compiled HLO
is O(1) in depth — essential to compile 88-layer models against a 512-device
mesh.  Remainder layers (``tail_pattern``) run unscanned after the scan;
zamba2's shared attention block lives outside the scan and is re-applied
with the same weights.

Param pytrees are mirrored by an *axes* pytree giving each leaf's preferred
mesh axes; the launcher resolves those to NamedShardings, dropping any axis
that does not divide the dimension (divisibility-aware planner; see
DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import (
    NOSHARD,
    ShardCtx,
    attention,
    attention_axes,
    decode_attention,
    init_attention,
    init_mlp,
    mlp,
    mlp_axes,
    rms_norm,
    trunc_normal,
)
from .mamba2 import (
    init_mamba2,
    mamba2_axes,
    mamba2_decode,
    mamba2_forward,
    mamba2_init_cache,
    _dims as mamba_dims,
)
from .moe import init_moe, moe_axes, moe_ffn


# ===========================================================================
# parameter construction
# ===========================================================================
def _init_block(key, kind: str, cfg: ModelConfig, dtype=jnp.bfloat16):
    if kind == "ssm":
        k1, _ = jax.random.split(key)
        return {"ln": jnp.ones((cfg.d_model,), jnp.float32),
                "mamba": init_mamba2(k1, cfg, dtype)}
    if kind == "shared_attn":
        return {}  # weights live in params['shared']
    k1, k2 = jax.random.split(key)
    block = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": init_attention(k1, cfg, dtype),
    }
    if kind == "moe":
        block["moe"] = init_moe(k2, cfg, dtype)
    else:
        block["mlp"] = init_mlp(k2, cfg, dtype)
    return block


def _block_axes(kind: str, cfg: ModelConfig):
    if kind == "ssm":
        return {"ln": (None,), "mamba": mamba2_axes(cfg)}
    if kind == "shared_attn":
        return {}
    a = {"ln1": (None,), "ln2": (None,), "attn": attention_axes(cfg)}
    if kind == "moe":
        a["moe"] = moe_axes(cfg)
    else:
        a["mlp"] = mlp_axes(cfg)
    return a


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    keys = jax.random.split(key, cfg.num_layers + 4)
    params: Dict = {}
    if not cfg.embedding_stub:
        params["embed"] = trunc_normal(keys[0], (cfg.vocab_size, cfg.d_model),
                                       1.0, dtype)
    if not cfg.tie_embeddings or cfg.embedding_stub:
        params["lm_head"] = trunc_normal(keys[1], (cfg.d_model, cfg.vocab_size),
                                         1.0, dtype)
    # scanned periods: stack each pattern position across periods
    per_period = []
    ki = 2
    for rep in range(cfg.num_periods):
        blocks = []
        for kind in cfg.layer_pattern:
            blocks.append(_init_block(keys[ki % len(keys)], kind, cfg, dtype))
            ki += 1
        per_period.append(tuple(blocks))
    params["scan"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period) \
        if cfg.num_periods > 1 else jax.tree.map(lambda x: x[None], per_period[0])
    params["tail"] = tuple(
        _init_block(keys[(ki + i) % len(keys)], kind, cfg, dtype)
        for i, kind in enumerate(cfg.tail_pattern)
    )
    if cfg.shared_attention:
        params["shared"] = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attention(keys[-2], cfg, dtype),
            "mlp": init_mlp(keys[-1], cfg, dtype),
        }
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params


def param_axes(cfg: ModelConfig) -> Dict:
    axes: Dict = {}
    if not cfg.embedding_stub:
        axes["embed"] = ("model", "data")
    if not cfg.tie_embeddings or cfg.embedding_stub:
        axes["lm_head"] = ("data", "model")
    period_axes = tuple(_block_axes(kind, cfg) for kind in cfg.layer_pattern)
    # scanned leaves gain a leading (periods) dim -> prepend None
    axes["scan"] = jax.tree.map(
        lambda a: (None,) + tuple(a),
        period_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(y, (str, type(None))) for y in x),
    )
    axes["tail"] = tuple(_block_axes(kind, cfg) for kind in cfg.tail_pattern)
    if cfg.shared_attention:
        axes["shared"] = {
            "ln1": (None,), "ln2": (None,),
            "attn": attention_axes(cfg), "mlp": mlp_axes(cfg),
        }
    axes["final_norm"] = (None,)
    return axes


# ===========================================================================
# forward (train / prefill)
# ===========================================================================
def _apply_block(kind: str, bp, shared, h, cfg: ModelConfig, ctx: ShardCtx):
    plus_one = cfg.scale_embeddings  # gemma-family norms use (1 + w)
    if kind == "ssm":
        return h + mamba2_forward(bp["mamba"], rms_norm(h, bp["ln"]), cfg, ctx)
    if kind == "shared_attn":
        bp = shared
    window = cfg.sliding_window if kind == "local" else None
    a = attention(bp["attn"], rms_norm(h, bp["ln1"], plus_one=plus_one),
                  cfg, ctx, sliding_window=window)
    h = h + a
    ff_in = rms_norm(h, bp["ln2"], plus_one=plus_one)
    if "moe" in bp:
        f = moe_ffn(bp["moe"], ff_in, cfg, ctx)
    else:
        f = mlp(bp["mlp"], ff_in, cfg, ctx)
    return h + f


# Remat/scan structure selector (perf hillclimb, EXPERIMENTS.md §Perf):
#   'per_period' — baseline: remat each period; the scan saves one carry per
#                  period (L * B * S * D bf16 — dominates HBM at depth 88)
#   'sqrt'       — nested scan: outer scan over groups of SQRT_GROUP periods
#                  saves L/k carries; the inner k periods recompute in the
#                  backward pass (classic sqrt(L) checkpointing)
_REMAT_MODE = "per_period"
SQRT_GROUP = 8


def set_remat_mode(name: str) -> None:
    global _REMAT_MODE
    assert name in ("per_period", "sqrt")
    _REMAT_MODE = name


def forward(
    params: Dict,
    cfg: ModelConfig,
    inputs: jnp.ndarray,  # (B, S) int32 tokens, or (B, S, D) embeddings (stub)
    ctx: ShardCtx = NOSHARD,
    remat: bool = True,
) -> jnp.ndarray:
    if cfg.embedding_stub:
        h = inputs.astype(jnp.bfloat16)
    else:
        h = jnp.take(params["embed"], inputs, axis=0)
        if cfg.scale_embeddings:
            h = h * np.sqrt(cfg.d_model).astype(np.float32)
        h = h.astype(jnp.bfloat16)
    h = ctx.constrain(h, (ctx.dp_spec, None, None))
    shared = params.get("shared")

    def period_body(carry, block_slice):
        hh = carry
        for kind, bp in zip(cfg.layer_pattern, block_slice):
            hh = _apply_block(kind, bp, shared, hh, cfg, ctx)
        hh = ctx.constrain(hh, (ctx.dp_spec, None, None))
        return hh, None

    group = SQRT_GROUP
    if _REMAT_MODE == "sqrt" and remat and cfg.num_periods % group == 0 \
            and cfg.num_periods > group:
        grouped = jax.tree.map(
            lambda x: x.reshape((cfg.num_periods // group, group) + x.shape[1:]),
            params["scan"])

        def group_body(carry, group_slice):
            hh = carry
            for j in range(group):
                blk = jax.tree.map(lambda x: x[j], group_slice)
                hh, _ = jax.checkpoint(period_body)(hh, blk)
            return hh, None

        h, _ = jax.lax.scan(jax.checkpoint(group_body), h, xs=grouped)
    else:
        body = jax.checkpoint(period_body) if remat else period_body
        h, _ = jax.lax.scan(body, h, xs=params["scan"])
    for kind, bp in zip(cfg.tail_pattern, params["tail"]):
        h = _apply_block(kind, bp, shared, h, cfg, ctx)

    h = rms_norm(h, params["final_norm"], plus_one=cfg.scale_embeddings)
    if cfg.tie_embeddings and not cfg.embedding_stub:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    return ctx.constrain(logits, (ctx.dp_spec, None, ctx.tp))


# ===========================================================================
# decode (serve_step)
# ===========================================================================
def _cache_len(kind: str, cfg: ModelConfig, max_seq: int) -> int:
    if kind == "local" and cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_seq)
    return max_seq


def _init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16):
    if kind == "ssm":
        return mamba2_init_cache(cfg, batch)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    S = _cache_len(kind, cfg, max_seq)
    return {
        "k": jnp.zeros((batch, S, hkv, hd), dtype),
        "v": jnp.zeros((batch, S, hkv, hd), dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    per_period = tuple(
        _init_block_cache(kind, cfg, batch, max_seq)
        for kind in cfg.layer_pattern
    )
    cache = {
        "scan": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.num_periods,) + x.shape),
            per_period,
        ),
        "tail": tuple(
            _init_block_cache(kind, cfg, batch, max_seq)
            for kind in cfg.tail_pattern
        ),
    }
    return cache


def cache_axes(cfg: ModelConfig, batch: int, dp_over_seq: bool) -> Dict:
    """Sharding prefs for the cache: batch on data when it divides, else the
    sequence dim (long_500k, batch=1); kv-heads on model when divisible."""

    def attn_axes():
        if dp_over_seq:
            return {"k": ("data", None, "model", None) if False else
                         (None, "data", "model", None),
                    "v": (None, "data", "model", None)}
        return {"k": ("data", None, "model", None),
                "v": ("data", None, "model", None)}

    def block_axes(kind):
        if kind == "ssm":
            return {"conv": ("data", "model", None),
                    "ssd": ("data", "model", None, None)}
        return attn_axes()

    per = tuple(block_axes(k) for k in cfg.layer_pattern)
    return {
        "scan": jax.tree.map(
            lambda a: (None,) + tuple(a), per,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(y, (str, type(None))) for y in x),
        ),
        "tail": tuple(block_axes(k) for k in cfg.tail_pattern),
    }


def _decode_block(kind: str, bp, shared, h, cache, pos, cfg, ctx):
    plus_one = cfg.scale_embeddings
    if kind == "ssm":
        out, new_cache = mamba2_decode(bp["mamba"], rms_norm(h, bp["ln"]),
                                       cache, cfg, ctx)
        return h + out, new_cache
    if kind == "shared_attn":
        bp = shared
    window = cfg.sliding_window if kind == "local" else None
    a, nk, nv = decode_attention(
        bp["attn"], rms_norm(h, bp["ln1"], plus_one=plus_one),
        cache["k"], cache["v"], pos, cfg, ctx, sliding_window=window,
    )
    h = h + a
    ff_in = rms_norm(h, bp["ln2"], plus_one=plus_one)
    if "moe" in bp:
        f = moe_ffn(bp["moe"], ff_in, cfg, ctx)
    else:
        f = mlp(bp["mlp"], ff_in, cfg, ctx)
    return h + f, {"k": nk, "v": nv}


def decode_step(
    params: Dict,
    cache: Dict,
    inputs: jnp.ndarray,  # (B,) int32 token, or (B, 1, D) embedding (stub)
    pos: jnp.ndarray,  # scalar int32
    cfg: ModelConfig,
    ctx: ShardCtx = NOSHARD,
) -> Tuple[jnp.ndarray, Dict]:
    if cfg.embedding_stub:
        h = inputs.astype(jnp.bfloat16)
    else:
        h = jnp.take(params["embed"], inputs[:, None], axis=0)
        if cfg.scale_embeddings:
            h = h * np.sqrt(cfg.d_model).astype(np.float32)
        h = h.astype(jnp.bfloat16)
    shared = params.get("shared")

    def period_body(carry, xs):
        hh = carry
        block_slice, cache_slice = xs
        new_caches = []
        for kind, bp, cs in zip(cfg.layer_pattern, block_slice, cache_slice):
            hh, nc = _decode_block(kind, bp, shared, hh, cs, pos, cfg, ctx)
            new_caches.append(nc)
        return hh, tuple(new_caches)

    h, new_scan_cache = jax.lax.scan(
        period_body, h, xs=(params["scan"], cache["scan"])
    )
    new_tail = []
    for kind, bp, cs in zip(cfg.tail_pattern, params["tail"], cache["tail"]):
        h, nc = _decode_block(kind, bp, shared, h, cs, pos, cfg, ctx)
        new_tail.append(nc)

    h = rms_norm(h, params["final_norm"], plus_one=cfg.scale_embeddings)
    if cfg.tie_embeddings and not cfg.embedding_stub:
        logits = h[:, 0] @ params["embed"].T
    else:
        logits = h[:, 0] @ params["lm_head"]
    return logits, {"scan": new_scan_cache, "tail": tuple(new_tail)}
