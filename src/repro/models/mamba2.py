"""Mamba2 SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
intra-chunk compute (MXU-friendly) + a linear inter-chunk state recurrence —
O(S) total.  Decode is a constant-time state update.  The chunk kernel also
exists as a Pallas TPU kernel (repro.kernels.ssd_scan) validated against the
`ssd_reference` here.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import ShardCtx, rms_norm, trunc_normal


# ---------------------------------------------------------------------------
# reference SSD scan (shared with kernels/ssd_scan/ref.py)
# ---------------------------------------------------------------------------
def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., q) -> (..., q, q) with out[i,j] = sum_{k=j+1..i} x[k]; -inf above diag."""
    q = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # cum_i - cum_j
    mask = jnp.tril(jnp.ones((q, q), dtype=bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_reference(
    x: jnp.ndarray,  # (B, S, H, P) — already multiplied by dt
    dA: jnp.ndarray,  # (B, S, H) log-decays (dt * A, A < 0)
    Bm: jnp.ndarray,  # (B, S, N)
    Cm: jnp.ndarray,  # (B, S, N)
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD; returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} % chunk {q} != 0"
    nc = s // q
    xc = x.reshape(b, nc, q, h, p).astype(jnp.float32)
    Ac = dA.reshape(b, nc, q, h).transpose(0, 3, 1, 2).astype(jnp.float32)  # (b,h,nc,q)
    Bc = Bm.reshape(b, nc, q, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, q, n).astype(jnp.float32)

    A_cumsum = jnp.cumsum(Ac, axis=-1)  # (b,h,nc,q)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(Ac))  # (b,h,nc,q,q)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)

    # 2. chunk states (decay each position to chunk end)
    decay_states = jnp.exp(A_cumsum[..., -1:] - A_cumsum)  # (b,h,nc,q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # 3. inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    chunk_decay = jnp.exp(A_cumsum[..., -1])  # (b,h,nc)

    def step(carry, inp):
        st, dec = inp  # st: (b,h,p,n), dec: (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    states_t = states.transpose(1, 0, 2, 3, 4)  # (nc,b,h,p,n)
    decay_t = chunk_decay.transpose(2, 0, 1)  # (nc,b,h)
    final, prev_states = jax.lax.scan(step, initial_state.astype(jnp.float32),
                                      (states_t, decay_t))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    # 4. state -> output contribution
    state_decay_out = jnp.exp(A_cumsum)  # (b,h,nc,q)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay_out)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y, final


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, P, N)
    x: jnp.ndarray,  # (B, H, P) — dt-scaled input
    dA: jnp.ndarray,  # (B, H) log decay
    Bm: jnp.ndarray,  # (B, N)
    Cm: jnp.ndarray,  # (B, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    state = state * jnp.exp(dA)[..., None, None] \
        + jnp.einsum("bhp,bn->bhpn", x, Bm)
    y = jnp.einsum("bhpn,bn->bhp", state, Cm)
    return y, state


# ---------------------------------------------------------------------------
# the block
# ---------------------------------------------------------------------------
def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = s.num_heads(cfg.d_model)
    conv_dim = d_inner + 2 * s.d_state
    d_in_proj = 2 * d_inner + 2 * s.d_state + nheads
    return d_inner, nheads, conv_dim, d_in_proj


def init_mamba2(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    s = cfg.ssm
    d_inner, nheads, conv_dim, d_in_proj = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": trunc_normal(ks[0], (d, d_in_proj), 1.0, dtype),
        "conv_w": trunc_normal(ks[1], (conv_dim, s.d_conv), 1.0, jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": trunc_normal(ks[2], (d_inner, d), 1.0, dtype),
    }


def mamba2_axes(cfg: ModelConfig):
    return {
        "in_proj": ("data", "model"),
        "conv_w": ("model", None),
        "conv_b": ("model",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": ("model",),
        "out_proj": ("model", "data"),
    }


def _split_proj(zxbcdt, cfg):
    s = cfg.ssm
    d_inner, nheads, conv_dim, _ = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv: xBC (B, S, C), w (C, K)."""
    B, S, C = xBC.shape
    K = w.shape[1]
    x = xBC.astype(jnp.float32).transpose(0, 2, 1)  # (B, C, S)
    x = jnp.pad(x, ((0, 0), (0, 0), (K - 1, 0)))
    out = jax.lax.conv_general_dilated(
        x[:, :, None, :],  # (B, C, 1, S+K-1)
        w[:, None, None, :],  # (C, 1, 1, K)
        window_strides=(1, 1),
        padding="VALID",
        feature_group_count=C,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[:, :, 0, :]
    out = out + b[None, :, None]
    return jax.nn.silu(out).transpose(0, 2, 1)  # (B, S, C)


def mamba2_forward(p, x, cfg: ModelConfig, ctx: ShardCtx,
                   use_kernel: bool = False):
    """Training/prefill path: full-sequence chunked SSD."""
    s = cfg.ssm
    d_inner, nheads, conv_dim, _ = _dims(cfg)
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :d_inner].reshape(B, S, nheads, s.head_dim)
    Bm = xBC[..., d_inner: d_inner + s.d_state]
    Cm = xBC[..., d_inner + s.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    dA = dt * A[None, None, :]
    x_scaled = xs.astype(jnp.float32) * dt[..., None]
    if use_kernel:
        from ..kernels.registry import resolve
        y, _ = resolve("ssd_scan")(x_scaled, dA, Bm, Cm, s.chunk)
    else:
        y, _ = ssd_reference(x_scaled, dA, Bm, Cm, chunk=min(s.chunk, S))
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y.astype(x.dtype), p["norm"]) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = y @ p["out_proj"]
    return ctx.constrain(out, (ctx.dp_spec, None, None))


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, nheads, conv_dim, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, conv_dim, s.d_conv - 1), dtype),
        "ssd": jnp.zeros((batch, nheads, s.head_dim, s.d_state), dtype),
    }


def mamba2_decode(p, x, cache, cfg: ModelConfig, ctx: ShardCtx):
    """One-token decode: O(1) conv-buffer + state update. x: (B, 1, D)."""
    s = cfg.ssm
    d_inner, nheads, conv_dim, _ = _dims(cfg)
    B = x.shape[0]
    zxbcdt = (x @ p["in_proj"])[:, 0]  # (B, d_in_proj)
    z, xBC, dt = _split_proj(zxbcdt, cfg)

    window = jnp.concatenate(
        [cache["conv"], xBC.astype(cache["conv"].dtype)[:, :, None]], axis=2
    )  # (B, conv_dim, K)
    conv_out = jnp.einsum("bck,ck->bc", window, p["conv_w"]) + p["conv_b"]
    xBC_t = jax.nn.silu(conv_out)
    new_conv = window[:, :, 1:]

    xs = xBC_t[..., :d_inner].reshape(B, nheads, s.head_dim)
    Bm = xBC_t[..., d_inner: d_inner + s.d_state]
    Cm = xBC_t[..., d_inner + s.d_state:]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = dtv * A[None, :]
    y, new_state = ssd_decode_step(cache["ssd"], xs * dtv[..., None], dA, Bm, Cm)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y.astype(x.dtype), p["norm"]) * \
        jax.nn.silu(z.astype(jnp.float32))[:, None, :].astype(x.dtype)
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "ssd": new_state}
