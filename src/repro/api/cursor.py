"""DB-API-style cursor: execute/executemany + streamed fetches.

Results stay columnar (``VectorBatch``) inside the cursor; ``fetchone`` /
``fetchmany`` materialize row tuples only for the slice being fetched, so
paging through a large result never converts the whole batch at once.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from ..core.runtime.cancel import QueryCancelledError as _CoreCancelled
from ..core.runtime.exec import ExecError, MemoryPressureError
from ..core.runtime.wlm import QueryKilledError as _CoreKilled
from ..core.session import QueryResult
from ..core.sql.binder import BindError
from ..core.sql.parser import parse
from ..core.metastore import TxnAborted, WriteConflict
from .exceptions import (
    DatabaseError,
    Error,
    IntegrityError,
    InterfaceError,
    OperationalError,
    ProgrammingError,
    QueryCancelledError,
    QueryKilledError,
)

# numpy dtype kind -> SQL type name surfaced in Cursor.description
_TYPE_CODES = {"i": "BIGINT", "u": "BIGINT", "f": "DOUBLE", "b": "BOOLEAN"}

_DML_COUNTERS = ("inserted", "updated", "deleted")


def _translate_error(exc: Exception) -> Exception:
    if isinstance(exc, Error):
        return exc  # already a DB-API error; don't re-wrap
    if isinstance(exc, _CoreKilled):
        return QueryKilledError(str(exc))
    if isinstance(exc, _CoreCancelled):
        return QueryCancelledError(str(exc))
    if isinstance(exc, (SyntaxError, BindError, KeyError, ValueError)):
        return ProgrammingError(str(exc))
    if isinstance(exc, (WriteConflict, TxnAborted)):
        return IntegrityError(str(exc))
    if isinstance(exc, (MemoryPressureError, ExecError, OSError)):
        return OperationalError(str(exc))
    return DatabaseError(str(exc))


class Cursor:
    """Created via :meth:`repro.api.Connection.cursor`."""

    def __init__(self, connection):
        self._conn = connection
        self._closed = False
        self.arraysize = 1  # DB-API default page size for fetchmany()
        self._reset()

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def execute(self, operation: str, params: Optional[Sequence] = None
                ) -> "Cursor":
        """Execute a statement; ``?`` placeholders bind from ``params``.

        A thin blocking wrapper over the asynchronous handle path: the
        statement is submitted via :meth:`Connection.execute_async` (so it
        takes the same WLM-admitted scheduler route as every other query)
        and awaited to completion.
        """
        self._check_open()
        handle = self._conn.execute_async(operation, params)
        self._install(handle._wait_result())  # noqa: SLF001 - same package
        return self

    def executemany(self, operation: str,
                    seq_of_params: Sequence[Sequence]) -> "Cursor":
        """Run one statement against every parameter set (parsed once)."""
        self._check_open()
        try:
            stmt = parse(operation)
        except SyntaxError as exc:
            raise ProgrammingError(str(exc)) from exc
        total = 0
        result = None
        for params in seq_of_params:
            try:
                result = self._session.execute_stmt(stmt, operation,
                                                    _params(params))
            except Exception as exc:  # noqa: BLE001
                raise _translate_error(exc) from exc
            total += max(_rowcount_of(result), 0)
        if result is None:
            self._reset()
        else:
            self._install(result)
        self.rowcount = total
        return self

    # ------------------------------------------------------------------
    # fetching
    # ------------------------------------------------------------------
    def fetchone(self) -> Optional[tuple]:
        rows = self.fetchmany(1)
        return rows[0] if rows else None

    def fetchmany(self, size: Optional[int] = None) -> List[tuple]:
        self._check_open()
        if self._batch is None:
            raise InterfaceError("no result set: call execute() first")
        size = self.arraysize if size is None else size
        if size <= 0:
            return []
        page = self._batch.slice(self._pos, self._pos + size)
        self._pos += page.num_rows
        return page.to_rows()

    def fetchall(self) -> List[tuple]:
        self._check_open()
        if self._batch is None:
            raise InterfaceError("no result set: call execute() first")
        rest = self._batch.slice(self._pos, self._batch.num_rows)
        self._pos = self._batch.num_rows
        return rest.to_rows()

    def __iter__(self):
        return self

    def __next__(self) -> tuple:
        row = self.fetchone()
        if row is None:
            raise StopIteration
        return row

    # ------------------------------------------------------------------
    # metadata / lifecycle
    # ------------------------------------------------------------------
    @property
    def connection(self):
        return self._conn

    @property
    def info(self) -> dict:
        """Engine-side execution info of the last statement (cache hits,
        per-stage timings, DAG edges, ...)."""
        return dict(self._info)

    def setinputsizes(self, sizes) -> None:  # PEP 249: may be a no-op
        pass

    def setoutputsize(self, size, column=None) -> None:
        pass

    def close(self) -> None:
        self._closed = True
        self._reset()

    def __enter__(self) -> "Cursor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    @property
    def _session(self):
        return self._conn._session  # noqa: SLF001 - same package

    def _check_open(self) -> None:
        if self._closed:
            raise InterfaceError("cursor is closed")
        self._conn._check_open()  # noqa: SLF001

    def _reset(self) -> None:
        self._batch = None
        self._pos = 0
        self._info: dict = {}
        self.description: Optional[List[Tuple]] = None
        self.rowcount = -1

    def _install(self, result: QueryResult) -> None:
        self._info = result.info
        is_query = bool(result.batch.cols) or not any(
            k in result.info for k in _DML_COUNTERS
        )
        if result.batch.cols:
            self._batch = result.batch
            self._pos = 0
            self.description = [
                (_base_name(c), _TYPE_CODES.get(v.dtype.kind, "STRING"),
                 None, None, None, None, True)
                for c, v in result.batch.cols.items()
            ]
            self.rowcount = result.num_rows
        else:
            self._batch = None
            self._pos = 0
            self.description = None
            self.rowcount = _rowcount_of(result) if not is_query else 0


def _params(params: Optional[Sequence]) -> tuple:
    if params is None:
        return ()
    if isinstance(params, (str, bytes)):
        raise ProgrammingError("params must be a sequence of values, "
                               "not a string")
    return tuple(params)


def _rowcount_of(result: QueryResult) -> int:
    if any(k in result.info for k in _DML_COUNTERS):
        return sum(int(result.info.get(k, 0)) for k in _DML_COUNTERS)
    return result.num_rows if result.batch.cols else -1


def _base_name(qualified: str) -> str:
    return qualified.split(".", 1)[1] if "." in qualified else qualified
