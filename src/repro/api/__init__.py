"""Public client API for the warehouse (DB-API 2.0 flavored).

The paper's §2 architecture keeps the client protocol (HiveServer2 / JDBC)
separate from the query driver; this package is that front-end for the
reproduction:

    import repro.api as db

    with db.connect("/data/warehouse", engine="auto") as conn:
        cur = conn.cursor()
        cur.execute("SELECT region, SUM(amount) FROM sales "
                    "WHERE amount > ? GROUP BY region", (100.0,))
        print(cur.description)
        for row in cur.fetchmany(64):
            ...

    ps = conn.prepare("SELECT * FROM sales WHERE region = ?")
    ps.execute(("EMEA",)).fetchall()   # plan cached across executions

Module globals follow PEP 249: ``apilevel``, ``threadsafety`` (connections
may be shared across threads), and ``paramstyle`` (``qmark``: ``?``).
"""
from .connection import Connection, connect
from .cursor import Cursor
from .exceptions import (
    DatabaseError,
    DataError,
    Error,
    IntegrityError,
    InterfaceError,
    InternalError,
    NotSupportedError,
    OperationalError,
    ProgrammingError,
    Warning,
)
from .prepared import PreparedStatement

apilevel = "2.0"
threadsafety = 2
paramstyle = "qmark"

__all__ = [
    "Connection", "Cursor", "PreparedStatement", "connect",
    "apilevel", "threadsafety", "paramstyle",
    "Warning", "Error", "InterfaceError", "DatabaseError", "DataError",
    "OperationalError", "IntegrityError", "InternalError",
    "ProgrammingError", "NotSupportedError",
]
