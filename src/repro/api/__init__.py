"""Public client API for the warehouse (DB-API 2.0 flavored).

The paper's §2 architecture keeps the client protocol (HiveServer2 / JDBC)
separate from the query driver; this package is that front-end for the
reproduction:

    import repro.api as db

    with db.connect("/data/warehouse", engine="auto") as conn:
        cur = conn.cursor()
        cur.execute("SELECT region, SUM(amount) FROM sales "
                    "WHERE amount > ? GROUP BY region", (100.0,))
        print(cur.description)
        for row in cur.fetchmany(64):
            ...

    ps = conn.prepare("SELECT * FROM sales WHERE region = ?")
    ps.execute(("EMEA",)).fetchall()   # plan cached across executions

Statements can also run without blocking: ``conn.execute_async(sql)``
returns a :class:`~repro.api.handle.QueryHandle` immediately.  The query
executes on the warehouse's scheduler worker pool behind workload-manager
admission (per-pool ``query_parallelism``; paper §5.2); the handle can be
polled for progress, cancelled, awaited (``result(timeout)``), or iterated
with ``fetch_stream()``, which yields row batches while the query is still
running.  The blocking ``Cursor.execute`` is a thin wrapper over this same
path, so there is one execution route for all clients.

Module globals follow PEP 249: ``apilevel``, ``threadsafety`` (connections
may be shared across threads), and ``paramstyle`` (``qmark``: ``?``).
"""
from .connection import Connection, connect
from .cursor import Cursor
from .exceptions import (
    DatabaseError,
    DataError,
    Error,
    IntegrityError,
    InterfaceError,
    InternalError,
    NotSupportedError,
    OperationalError,
    ProgrammingError,
    QueryCancelledError,
    QueryKilledError,
    Warning,
)
from .handle import QueryHandle
from .prepared import PreparedStatement

apilevel = "2.0"
threadsafety = 2
paramstyle = "qmark"

__all__ = [
    "Connection", "Cursor", "PreparedStatement", "QueryHandle", "connect",
    "apilevel", "threadsafety", "paramstyle",
    "Warning", "Error", "InterfaceError", "DatabaseError", "DataError",
    "OperationalError", "IntegrityError", "InternalError",
    "ProgrammingError", "NotSupportedError",
    "QueryKilledError", "QueryCancelledError",
]
