"""Connection layer: ``connect()`` -> :class:`Connection` -> cursors.

Mirrors the HiveServer2/JDBC split of the paper's §2 architecture: the
connection owns client protocol state (config validation, session, prepared
statements) while all query driving lives in ``repro.core`` behind the
staged ``QueryPipeline``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from ..core.config_keys import DEFAULT_CONFIG, check_value
from ..core.session import Warehouse, _VALID_ENGINES
from .cursor import Cursor, _params, _translate_error
from .exceptions import InterfaceError, NotSupportedError, ProgrammingError
from .handle import QueryHandle
from .prepared import PreparedStatement


def connect(warehouse_dir: Optional[str] = None, *,
            warehouse: Optional[Warehouse] = None, **config) -> "Connection":
    """Open a connection to a warehouse directory.

    Pass either ``warehouse_dir`` (a path; the warehouse is created/opened
    there and owned by the connection) or ``warehouse=`` (attach to an
    existing :class:`Warehouse`, e.g. to share one across connections).
    Remaining keyword arguments override session config defaults
    (declared once in ``repro.core.config_keys``), e.g. ``engine="ref"`` or
    ``result_cache=False``.
    """
    if (warehouse_dir is None) == (warehouse is None):
        raise InterfaceError(
            "pass exactly one of warehouse_dir or warehouse="
        )
    unknown = set(config) - set(DEFAULT_CONFIG)
    if unknown:
        raise ProgrammingError(
            f"unknown config option(s): {sorted(unknown)}; "
            f"valid options: {sorted(DEFAULT_CONFIG)}"
        )
    for name, value in config.items():
        complaint = check_value(name, value)
        if complaint is not None:
            raise ProgrammingError(complaint)
    if config.get("engine", DEFAULT_CONFIG["engine"]) not in _VALID_ENGINES:
        raise ProgrammingError(
            f"engine must be one of {_VALID_ENGINES}"
        )
    owns = warehouse is None
    wh = warehouse if warehouse is not None else Warehouse(warehouse_dir)
    return Connection(wh, config, owns_warehouse=owns)


class Connection:
    """A client session over one warehouse; create with :func:`connect`."""

    def __init__(self, warehouse: Warehouse, config: dict,
                 owns_warehouse: bool = True):
        self._wh = warehouse
        self._session = warehouse.session(**config)
        self._owns_warehouse = owns_warehouse
        self._closed = False

    # ------------------------------------------------------------------
    @property
    def warehouse(self) -> Warehouse:
        return self._wh

    @property
    def session(self):
        """The underlying ``repro.core.session.Session`` (escape hatch)."""
        return self._session

    @property
    def closed(self) -> bool:
        return self._closed

    def cursor(self) -> Cursor:
        self._check_open()
        return Cursor(self)

    def catalogs(self) -> dict:
        """Mounted federated catalogs: ``{name: connector}`` (paper §6).

        Catalogs are created with ``CREATE CATALOG name USING connector
        [WITH (...)]`` and queried with three-part names
        (``catalog.schema.table``); schemas are discovered lazily from the
        remote system.  Use ``conn.warehouse.catalogs.get(name)`` for the
        full :class:`~repro.core.federation.catalog.Catalog` object
        (``list_schemas()`` / ``list_tables()``)."""
        self._check_open()
        return {name: cat.connector
                for name, cat in self._wh.catalogs.items()}

    def server_stats(self) -> dict:
        """Serving-tier counters for the shared warehouse: result-cache
        hits/misses/evictions/bytes, shared-scan publishes/attaches, and
        per-pool admission queue depths.  Counters are warehouse-wide
        (every connection sees the same serving tier)."""
        self._check_open()
        return self._wh.serving_stats()

    # ------------------------------------------------------------------
    # observability (PR 10)
    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Snapshot of the warehouse :class:`MetricsRegistry` — every
        counter/gauge/histogram the serving tier, WLM, exchanges, and
        query driver report — plus per-``kernel[backend]`` dispatch counts
        from the engine registry.  Shape:
        ``{"counters": {...}, "gauges": {...}, "histograms": {...}}``."""
        self._check_open()
        from ..kernels.registry import dispatch_counts

        out = self._wh.obs.metrics.snapshot()
        for name, n in dispatch_counts().items():
            out["counters"][f"kernels.dispatch.{name}"] = n
        return out

    def query_log(self, limit: Optional[int] = None) -> List[dict]:
        """The warehouse's bounded ring of recently finished queries
        (always on, newest last): qid, sql, status, wall/queue-wait ms,
        rows, pool, cache_hit, error.  ``limit`` trims to the most recent
        N entries."""
        self._check_open()
        return self._wh.obs.query_log.entries(limit)

    def export_trace(self, query_id: str, path: str) -> str:
        """Write the stored :class:`QueryTrace` for ``query_id`` as Chrome
        trace-event JSON (open in Perfetto / ``chrome://tracing``).
        Requires the query to have run with tracing on (``obs.tracing``
        config or ``REPRO_OBS_TRACING=1``).  Returns ``path``."""
        self._check_open()
        return self._wh.obs.export_trace(query_id, path)

    def prepare(self, sql: str) -> PreparedStatement:
        """Parse + bind + optimize ``sql`` once; re-executions reuse the
        cached plan (see ``repro.core.pipeline.PlanCache``)."""
        self._check_open()
        return PreparedStatement(self, sql)

    def execute(self, sql: str, params: Optional[Sequence] = None) -> Cursor:
        """Convenience: ``conn.cursor().execute(sql, params)``."""
        return self.cursor().execute(sql, params)

    def execute_async(self, sql: str,
                      params: Optional[Sequence] = None) -> QueryHandle:
        """Submit a statement without blocking; returns a
        :class:`~repro.api.handle.QueryHandle` to poll, stream, cancel, or
        await.  Queries are admitted through the active workload-manager
        resource plan (per-pool ``query_parallelism``; paper §5.2) on the
        warehouse's shared scheduler.  Parsing runs synchronously, so syntax
        and parameter-arity errors raise here, not from the handle."""
        self._check_open()
        try:
            task = self._session.submit(sql, _params(params))
        except Exception as exc:  # noqa: BLE001 - translated to DB-API
            raise _translate_error(exc) from exc
        return QueryHandle(self, task)

    # ------------------------------------------------------------------
    # transaction surface: statements run under single-statement ACID
    # transactions (paper §3.2), i.e. autocommit
    # ------------------------------------------------------------------
    def commit(self) -> None:
        self._check_open()  # every statement auto-commits; nothing pending

    def rollback(self) -> None:
        self._check_open()
        raise NotSupportedError(
            "statements auto-commit under single-statement transactions"
        )

    # ------------------------------------------------------------------
    def close(self) -> None:
        if not self._closed and self._owns_warehouse:
            self._wh.close()  # attached warehouses outlive the connection
        self._closed = True

    def __enter__(self) -> "Connection":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise InterfaceError("connection is closed")
