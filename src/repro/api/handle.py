"""Asynchronous query handles (the client side of HS2 async operations).

``Connection.execute_async(sql, params)`` returns a :class:`QueryHandle`
immediately; the statement runs on the warehouse's scheduler worker pool
behind workload-manager admission (paper §5.2).  The handle exposes:

  * ``state`` — QUEUED / ADMITTED / RUNNING / SUCCEEDED / FAILED / CANCELLED;
  * ``poll()`` — progress: DAG vertices done/total, WLM pool, queue wait,
    rows/bytes spilled per vertex by the spill-aware exchanges, and the
    per-pool admission queue depth;
  * ``result(timeout)`` — block for completion, return a :class:`Cursor`
    over the result set (raises the query's error on failure);
  * ``cancel()`` — cooperative cancellation, observed while queued for
    admission and at every operator batch boundary (latency bounded by one
    morsel);
  * ``fetch_stream()`` — iterate row batches as the engine produces them:
    root-vertex morsels stream out while upstream DAG vertices are still
    running, so first rows arrive long before the handle reaches SUCCEEDED
    (a lagging consumer backpressures the executing worker; upstream
    vertices keep going, bounded by the exchanges' spill budget).

Queries killed by a WLM trigger rule raise
:class:`repro.api.exceptions.QueryKilledError` from ``result()`` /
``fetch_stream()``; client-cancelled queries raise
:class:`repro.api.exceptions.QueryCancelledError`.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

from ..core.runtime import scheduler as _sched
from .cursor import Cursor, _translate_error


class QueryHandle:
    """Created via :meth:`repro.api.Connection.execute_async`."""

    def __init__(self, connection, task: _sched.QueryTask):
        self._conn = connection
        self._task = task
        self._cursor: Optional[Cursor] = None

    # ------------------------------------------------------------- state
    @property
    def query_id(self) -> str:
        return self._task.qid

    @property
    def state(self) -> str:
        """QUEUED | ADMITTED | RUNNING | SUCCEEDED | FAILED | CANCELLED."""
        return self._task.state

    def done(self) -> bool:
        return self._task.done()

    def poll(self) -> dict:
        """Non-blocking progress snapshot: ``state``, ``pool``,
        ``vertices_done``/``vertices_total``, ``queue_wait_ms``,
        ``spill`` (per-vertex rows/bytes spilled by the exchanges),
        ``rows_spilled``/``bytes_spilled`` totals, and
        ``pool_queue_depth`` (queued queries per WLM pool)."""
        return self._task.poll()

    @property
    def info(self) -> dict:
        """Engine-side execution info once the query succeeded."""
        res = self._task.result
        return dict(res.info) if res is not None else {}

    def trace(self) -> dict:
        """This query's Chrome trace-event JSON (paper-style EXPLAIN
        ANALYZE's raw material): pipeline-stage spans, WLM admission wait,
        per-vertex compute/exchange-wait/spill-I/O tracks, shuffle lanes,
        and serving/adaptive instant events.  Requires ``obs.tracing``
        (or ``REPRO_OBS_TRACING=1``) to have been on when the query was
        submitted; dump to a file and open in Perfetto, or use
        ``Connection.export_trace(handle.query_id, path)``."""
        if self._task.trace is None:
            raise RuntimeError(
                "query ran with tracing off; submit with obs.tracing=True "
                "(connect(..., **{'obs.tracing': True}) or "
                "REPRO_OBS_TRACING=1) to record a trace")
        return self._task.trace.to_chrome()

    # ------------------------------------------------------------- results
    def result(self, timeout: Optional[float] = None) -> Cursor:
        """Block until the query finishes; return a cursor over the result.

        Raises ``TimeoutError`` if still running after ``timeout`` seconds,
        or the query's (DB-API-translated) error if it failed, was killed,
        or was cancelled.
        """
        res = self._wait_result(timeout)
        if self._cursor is None:
            self._cursor = Cursor(self._conn)
            self._cursor._install(res)  # noqa: SLF001 - same package
        return self._cursor

    def cancel(self) -> bool:
        """Request cooperative cancellation (observed at DAG vertex
        boundaries and in the admission queue).  Returns ``False`` when the
        query already completed."""
        return self._task.cancel()

    def fetch_stream(self, batch_rows: Optional[int] = None
                     ) -> Iterator[List[tuple]]:
        """Yield result rows in batches as the engine produces them.

        While the query is in flight, the root vertex's morsels stream from
        the executing worker as they are produced — the first batch arrives
        before the root vertex (let alone the DAG) finishes, upstream
        vertices report through :meth:`poll` as they go, and rows are handed
        over in ``batch_rows``-row slices (default: session config
        ``stream_batch_rows``).  On a finished handle the final result is
        replayed in slices instead, so the method is safe to call at any
        point.  Raises like :meth:`result` if the query failed.
        """
        task = self._task
        if task.stream.activate(batch_rows):
            for batch in task.stream:
                yield batch.to_rows()
            if task.done() and task.error is not None:
                self._wait_result()  # raises the translated error
            return
        # producer already passed its emit point: replay the final result
        res = self._wait_result()
        rows = int(batch_rows or _sched.stream_batch_rows(task.config))
        for piece in _sched.ResultStream.iter_slices(res.batch, rows):
            yield piece.to_rows()

    # ------------------------------------------------------------- internals
    def _wait_result(self, timeout: Optional[float] = None):
        try:
            return self._task.wait(timeout)
        except TimeoutError:
            raise
        except Exception as exc:  # noqa: BLE001 - translated to DB-API
            raise _translate_error(exc) from exc

    def __repr__(self):
        return (f"QueryHandle({self.query_id}, {self.state}, "
                f"sql={self._task.sql!r})")
