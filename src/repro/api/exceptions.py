"""DB-API 2.0 (PEP 249) exception hierarchy for the client layer."""
from __future__ import annotations


class Warning(Exception):  # noqa: A001 - PEP 249 name
    """Important warnings, e.g. data truncation during inserts."""


class Error(Exception):
    """Base of all other error exceptions."""


class InterfaceError(Error):
    """Errors related to the interface itself (e.g. closed cursor use)."""


class DatabaseError(Error):
    """Errors related to the warehouse."""


class DataError(DatabaseError):
    """Problems with the processed data (bad cast, value out of range)."""


class OperationalError(DatabaseError):
    """Errors in the warehouse's operation (memory pressure, I/O, ...)."""


class IntegrityError(DatabaseError):
    """Relational integrity violations (write conflicts, aborted txns)."""


class InternalError(DatabaseError):
    """The warehouse hit an internal inconsistency."""


class ProgrammingError(DatabaseError):
    """SQL syntax errors, missing tables, wrong parameter counts, ..."""


class NotSupportedError(DatabaseError):
    """A method or API the warehouse does not support (e.g. rollback)."""


class QueryKilledError(OperationalError):
    """The workload manager killed the query via a trigger rule (§5.2)."""


class QueryCancelledError(OperationalError):
    """The query was cancelled through :meth:`QueryHandle.cancel`."""
