"""Prepared statements: parse/bind/optimize once, execute many times.

``prepare()`` runs the planning half of the query pipeline immediately and
parks the optimized logical plan in the warehouse-wide plan cache (keyed by
statement text + planning config, like the query-result cache is keyed by
resolved query identity).  ``execute(params)`` then enters the pipeline with
the pre-parsed AST; the Bind stage's plan-cache probe skips parse + bind +
optimize, and only compile + execute run per invocation.  ``?`` placeholders
remain :class:`repro.core.sql.ast.Param` nodes inside the cached plan, so
one plan serves every parameter binding.
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..core.pipeline import (
    PlanCache,
    QueryContext,
    QueryPipeline,
    plan_only_stages,
)
from ..core.sql import ast as A
from ..core.sql.binder import BindError
from ..core.sql.parser import parse
from .cursor import Cursor, _params, _translate_error
from .exceptions import ProgrammingError


class PreparedStatement:
    """Created via :meth:`repro.api.Connection.prepare`."""

    def __init__(self, connection, sql: str):
        self._conn = connection
        self.sql = sql
        try:
            self._stmt = parse(sql)
        except SyntaxError as exc:
            raise ProgrammingError(str(exc)) from exc
        if isinstance(self._stmt, A.Explain):
            raise ProgrammingError("cannot prepare EXPLAIN statements")
        self.is_query = isinstance(self._stmt, (A.Select, A.SetOp))
        self.param_count = A.count_params(self._stmt)
        if self.is_query:
            self._warm_plan_cache()

    def _warm_plan_cache(self) -> None:
        """Bind + optimize now so the first execute() already skips planning;
        also surfaces name-resolution errors at prepare time, like JDBC.
        The pipeline's Optimize stage fills the plan cache as a side effect
        (the context carries sql, so the cache key resolves)."""
        session = self._conn.session
        key = PlanCache.key_of(self.sql, session.config)
        if session.wh.plan_cache.get(key, session.hms) is not None:
            return
        try:
            q = QueryContext(session=session, sql=self.sql, stmt=self._stmt,
                             config=session.config)
            QueryPipeline(session, plan_only_stages()).run(q)
        except (BindError, KeyError) as exc:
            raise ProgrammingError(str(exc)) from exc

    def execute(self, params: Optional[Sequence] = None) -> Cursor:
        """Execute with the given parameter values; returns a fresh cursor."""
        values = _params(params)
        if len(values) != self.param_count:
            raise ProgrammingError(
                f"statement takes {self.param_count} parameter(s), "
                f"got {len(values)}"
            )
        cursor = self._conn.cursor()
        try:
            result = self._conn.session.execute_stmt(
                self._stmt, self.sql, values
            )
        except Exception as exc:  # noqa: BLE001 - translated to DB-API
            raise _translate_error(exc) from exc
        cursor._install(result)  # noqa: SLF001 - same package
        return cursor

    def __repr__(self):
        kind = "query" if self.is_query else "statement"
        return (f"PreparedStatement({kind}, params={self.param_count}, "
                f"sql={self.sql!r})")
