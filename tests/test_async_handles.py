"""Async query handles: concurrent execution, WLM admission gating,
cancellation, kill triggers, and streaming fetch (paper §2 HS2 + §5.2)."""
import time

import pytest

import repro.api as db


def wait_for(cond, timeout=10.0, interval=0.01, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out after {timeout}s waiting for {what}")


@pytest.fixture()
def conn(tmp_path):
    c = db.connect(str(tmp_path / "wh"))
    cur = c.cursor()
    cur.execute("CREATE TABLE t (k INT, v DOUBLE)")
    rows = ", ".join(f"({i % 50}, {i * 1.5})" for i in range(400))
    cur.execute(f"INSERT INTO t VALUES {rows}")
    yield c
    c.close()


TWO_POOL_DDL = [
    "CREATE RESOURCE PLAN duo",
    "CREATE POOL duo.a WITH alloc_fraction=0.5, query_parallelism=1",
    "CREATE POOL duo.b WITH alloc_fraction=0.5, query_parallelism=1",
    "CREATE APPLICATION MAPPING appA IN duo TO a",
    "CREATE APPLICATION MAPPING appB IN duo TO b",
    "ALTER PLAN duo SET DEFAULT POOL = a",
    "ALTER RESOURCE PLAN duo ENABLE ACTIVATE",
]


def activate_two_pools(conn):
    cur = conn.cursor()
    for ddl in TWO_POOL_DDL:
        cur.execute(ddl)


# ---------------------------------------------------------------------------
# handle basics
# ---------------------------------------------------------------------------
def test_handle_lifecycle_and_result(conn):
    h = conn.execute_async("SELECT k, SUM(v) AS s FROM t GROUP BY k ORDER BY k")
    cur = h.result(timeout=30)
    assert h.state == "SUCCEEDED" and h.done()
    assert len(cur.fetchall()) == 50
    p = h.poll()
    assert p["state"] == "SUCCEEDED"
    assert p["vertices_total"] >= 2  # scan + aggregate: a multi-vertex DAG
    assert p["vertices_done"] == p["vertices_total"]
    assert "dag_edges" in h.info
    # result() is idempotent: same cursor back
    assert h.result() is cur


def test_cursor_execute_wraps_handle_path(conn):
    """PEP-249 Cursor.execute is a blocking wrapper over execute_async."""
    cur = conn.cursor()
    cur.execute("SELECT k, SUM(v) AS s FROM t GROUP BY k ORDER BY k")
    sync_rows = cur.fetchall()
    assert cur.description[0][0] == "k"
    assert cur.rowcount == 50
    h = conn.execute_async("SELECT k, SUM(v) AS s FROM t GROUP BY k ORDER BY k")
    assert h.result(30).fetchall() == sync_rows


def test_async_dml_and_ddl(conn):
    h = conn.execute_async("INSERT INTO t VALUES (99, 1.0)")
    h.result(30)
    assert h.state == "SUCCEEDED"
    assert conn.execute("SELECT COUNT(*) FROM t").fetchone() == (401,)


def test_submit_errors_raise_synchronously(conn):
    with pytest.raises(db.ProgrammingError):
        conn.execute_async("SELEKT nope")
    with pytest.raises(db.ProgrammingError):
        conn.execute_async("SELECT k FROM t WHERE v > ?")  # missing param


def test_result_timeout(conn):
    slow = db.connect(warehouse=conn.warehouse, debug_vertex_delay_s=0.5,
                      result_cache=False)
    h = slow.execute_async("SELECT COUNT(*) FROM t")
    with pytest.raises(TimeoutError):
        h.result(timeout=0.05)
    h.result(30)  # then completes fine


# ---------------------------------------------------------------------------
# WLM admission gating
# ---------------------------------------------------------------------------
def test_pool_parallelism_serializes_within_pool(conn):
    """Two handles in a parallelism=1 pool run serially (second QUEUED until
    the first finishes) while a second pool keeps running concurrently."""
    activate_two_pools(conn)
    wh = conn.warehouse
    ca = db.connect(warehouse=wh, application="appA",
                    debug_vertex_delay_s=0.4, result_cache=False)
    cb = db.connect(warehouse=wh, application="appB",
                    debug_vertex_delay_s=1.5, result_cache=False)

    # occupy pool b for the whole test so pool a cannot borrow idle capacity
    hb = cb.execute_async("SELECT COUNT(*) FROM t")
    wait_for(lambda: hb.state == "RUNNING", what="hb running")
    h1 = ca.execute_async("SELECT SUM(v) FROM t")
    wait_for(lambda: h1.state == "RUNNING", what="h1 running")
    h2 = ca.execute_async("SELECT COUNT(*) FROM t WHERE k > 10")

    time.sleep(0.25)  # let h2's worker reach (and sit in) admission
    assert h1.state == "RUNNING"
    assert h2.state == "QUEUED"          # pool a saturated, b not idle
    assert hb.state == "RUNNING"         # second pool concurrent throughout

    assert h1.result(30).fetchall()
    assert h2.result(30).fetchall()
    assert hb.result(30).fetchall()
    assert h2.poll()["pool"] == "a"
    assert h2.poll()["queue_wait_ms"] > 100  # measurably queued behind h1
    for c in (ca, cb):
        c.close()


def test_saturated_pools_queue_instead_of_killing(conn):
    """Async admission queues when every pool is full (the sync path's
    admit-or-die only applies to direct Session.execute calls)."""
    activate_two_pools(conn)
    wh = conn.warehouse
    ca = db.connect(warehouse=wh, application="appA",
                    debug_vertex_delay_s=0.3, result_cache=False)
    handles = [ca.execute_async("SELECT SUM(v) FROM t WHERE k > ?", (i,))
               for i in range(4)]
    for h in handles:
        h.result(60)
    assert all(h.state == "SUCCEEDED" for h in handles)
    ca.close()


# ---------------------------------------------------------------------------
# kill triggers / cancellation
# ---------------------------------------------------------------------------
def test_kill_trigger_fails_running_handle(conn):
    activate_two_pools(conn)
    cur = conn.cursor()
    cur.execute("CREATE RULE reaper IN duo WHEN rows_produced > 10 THEN KILL")
    cur.execute("ALTER RESOURCE PLAN duo ENABLE ACTIVATE")
    ca = db.connect(warehouse=conn.warehouse, application="appA",
                    result_cache=False)
    h = ca.execute_async("SELECT k, v FROM t WHERE v >= 0")
    with pytest.raises(db.QueryKilledError):
        h.result(30)
    assert h.state == "FAILED"
    ca.close()


def test_cancel_during_execution_leaves_session_usable(conn):
    slow = db.connect(warehouse=conn.warehouse, debug_vertex_delay_s=0.5,
                      result_cache=False)
    h = slow.execute_async("SELECT k, SUM(v) FROM t GROUP BY k")
    wait_for(lambda: h.state == "RUNNING", what="handle running")
    assert h.cancel()
    wait_for(h.done, what="handle terminal")
    assert h.state == "CANCELLED"
    with pytest.raises(db.QueryCancelledError):
        h.result(5)
    # the same session keeps serving queries afterwards
    assert slow.execute("SELECT COUNT(*) FROM t").fetchone() == (400,)
    slow.close()


def test_cancel_while_queued(conn):
    activate_two_pools(conn)
    wh = conn.warehouse
    ca = db.connect(warehouse=wh, application="appA",
                    debug_vertex_delay_s=0.6, result_cache=False)
    cb = db.connect(warehouse=wh, application="appB",
                    debug_vertex_delay_s=0.6, result_cache=False)
    blockers = [ca.execute_async("SELECT SUM(v) FROM t"),
                cb.execute_async("SELECT SUM(v) FROM t")]
    wait_for(lambda: all(b.state == "RUNNING" for b in blockers),
             what="both pools busy")
    h = ca.execute_async("SELECT COUNT(*) FROM t")
    time.sleep(0.1)
    assert h.state == "QUEUED"
    h.cancel()
    wait_for(h.done, what="queued handle cancelled")
    assert h.state == "CANCELLED"
    for b in blockers:
        b.result(30)
    for c in (ca, cb):
        c.close()


# ---------------------------------------------------------------------------
# streaming fetch
# ---------------------------------------------------------------------------
def test_fetch_stream_yields_before_succeeded(conn):
    """On a multi-vertex DAG, at least one batch arrives while the handle is
    still short of SUCCEEDED (backpressure holds the worker in RUNNING)."""
    h = conn.execute_async(
        "SELECT k, COUNT(*) AS n, SUM(v) AS s FROM t GROUP BY k ORDER BY k"
    )
    assert h.poll()["state"] in ("QUEUED", "ADMITTED", "RUNNING")
    states, rows = [], []
    for batch in h.fetch_stream(batch_rows=10):  # 50 groups -> 5 batches
        states.append(h.state)
        rows.extend(batch)
    assert len(rows) == 50
    assert len(states) == 5
    assert states[0] != "SUCCEEDED"  # streamed while still executing
    wait_for(h.done, what="handle terminal")
    assert h.state == "SUCCEEDED"
    assert h.poll()["vertices_total"] >= 2


def test_fetch_stream_replays_after_completion(conn):
    h = conn.execute_async("SELECT k FROM t ORDER BY k")
    h.result(30)
    batches = list(h.fetch_stream(batch_rows=100))
    assert [len(b) for b in batches] == [100, 100, 100, 100]
    assert batches[0][0] == (0,)


def test_fetch_stream_raises_query_error(conn):
    slow = db.connect(warehouse=conn.warehouse, debug_vertex_delay_s=0.3,
                      result_cache=False)
    h = slow.execute_async("SELECT k, SUM(v) FROM t GROUP BY k")
    wait_for(lambda: h.state == "RUNNING", what="handle running")
    h.cancel()
    with pytest.raises(db.QueryCancelledError):
        for _ in h.fetch_stream():
            pass
    slow.close()


def test_concurrent_handles_all_succeed(conn):
    """A fan-out of concurrent handles on one warehouse stays correct."""
    expect = conn.execute("SELECT COUNT(*) FROM t").fetchone()
    handles = [conn.execute_async("SELECT COUNT(*) FROM t WHERE k >= ?", (k,))
               for k in [0] * 6]
    got = [h.result(60).fetchone() for h in handles]
    assert got == [expect] * 6


def test_explain_analyze_queues_behind_admission(conn):
    """EXPLAIN ANALYZE executes its query, so the async path admits it like
    one: with every pool saturated it queues instead of being killed."""
    activate_two_pools(conn)
    wh = conn.warehouse
    ca = db.connect(warehouse=wh, application="appA",
                    debug_vertex_delay_s=0.5, result_cache=False)
    cb = db.connect(warehouse=wh, application="appB",
                    debug_vertex_delay_s=0.5, result_cache=False)
    blockers = [ca.execute_async("SELECT SUM(v) FROM t"),
                cb.execute_async("SELECT SUM(v) FROM t")]
    wait_for(lambda: all(b.state == "RUNNING" for b in blockers),
             what="both pools busy")
    he = conn.execute_async("EXPLAIN ANALYZE SELECT k, SUM(v) FROM t GROUP BY k")
    time.sleep(0.15)
    assert he.state == "QUEUED"
    for b in blockers:
        b.result(30)
    lines = [r[0] for r in he.result(30).fetchall()]
    assert any("stage timings:" in line for line in lines)
    for c in (ca, cb):
        c.close()
