"""Adaptive execution: live-telemetry replanning (hot-lane split, payoff
collapse, co-partition shuffle elision, straggler speculation).

Every test runs with the structural DAG validator on (suite-wide autouse
fixture), so each mid-query mutation the adaptive layer adopts is
re-checked by ``repro.analysis.check_dag``.  Parity tests compare the
adaptive run's rowset against a run with ``adaptive.enabled = False`` on
the same warehouse.
"""
import tempfile

import numpy as np
import pytest

import repro.api as db
from repro.analysis import lockdep
from repro.core.acid import AcidTable
from repro.core.runtime.vector import VectorBatch
from repro.core.session import Warehouse

SKEW_N = 400_000
UNIF_N = 300_000
AUTO = {"shuffle.partitions": "auto", "result_cache": False}


def _load(wh, name, cols):
    tx = wh.hms.open_txn()
    AcidTable(wh.hms.get_table(name), wh.hms).insert(tx, VectorBatch(cols))
    wh.hms.commit_txn(tx)


def rowset(r):
    b = r.batch
    return sorted(zip(*[b.cols[c].tolist() for c in b.column_names]))


def kinds(r):
    return [e["kind"] for e in (r.info.get("adaptive") or [])]


@pytest.fixture(scope="module")
def wh():
    wh = Warehouse(tempfile.mkdtemp(prefix="adaptive_wh_"))
    s = wh.session()
    s.execute("CREATE TABLE skewed (k INT, v INT)")
    s.execute("CREATE TABLE big (k INT, v INT)")
    s.execute("CREATE TABLE dim (k INT, name INT)")
    rng = np.random.default_rng(7)
    k = rng.integers(0, 64, SKEW_N)
    k[rng.random(SKEW_N) < 0.85] = 7  # one key owns ~85% of the rows
    _load(wh, "skewed", {"k": k, "v": np.arange(SKEW_N) % 100})
    _load(wh, "big", {"k": rng.integers(0, 64, UNIF_N),
                      "v": np.arange(UNIF_N) % 100})
    _load(wh, "dim", {"k": np.arange(64), "name": np.arange(64) * 10})
    return wh


def run_pair(wh, sql, on_cfg=None, off_cfg=None):
    """(adaptive-on result, adaptive-off result) for the same query."""
    s_on = wh.session(**{**AUTO, **(on_cfg or {})})
    s_off = wh.session(**{**AUTO, "adaptive.enabled": False,
                          **(off_cfg or {})})
    return s_on.execute(sql), s_off.execute(sql)


# ===========================================================================
# hot-lane split
# ===========================================================================
class TestSkewSplit:
    def test_skewed_agg_parity_and_split_event(self, wh):
        r_on, r_off = run_pair(
            wh, "SELECT k, SUM(v) AS sv, COUNT(*) AS c FROM skewed "
                "GROUP BY k")
        assert rowset(r_on) == rowset(r_off)
        split = [e for e in r_on.info["adaptive"]
                 if e["kind"] == "lane_split"]
        assert split, r_on.info.get("adaptive")
        ev = split[0]
        assert ev["ways"] >= 2
        assert ev["lane_rows"] > ev["lane_median"]

    def test_skewed_min_max_parity(self, wh):
        # all foldable agg functions through the merge-fold rewrite
        r_on, r_off = run_pair(
            wh, "SELECT k, MIN(v) AS lo, MAX(v) AS hi, COUNT(*) AS c "
                "FROM skewed GROUP BY k")
        assert rowset(r_on) == rowset(r_off)

    def test_uniform_data_never_splits(self, wh):
        r_on, r_off = run_pair(
            wh, "SELECT k, SUM(v) AS sv FROM big GROUP BY k")
        assert rowset(r_on) == rowset(r_off)
        assert "lane_split" not in kinds(r_on)

    def test_distinct_agg_not_split(self, wh):
        # DISTINCT lanes own disjoint value ranges: round-robin sub-lanes
        # would double-count, so the split must never trigger there
        r_on, r_off = run_pair(
            wh, "SELECT COUNT(DISTINCT k) AS dk FROM skewed")
        assert rowset(r_on) == rowset(r_off)
        assert "lane_split" not in kinds(r_on)


# ===========================================================================
# payoff-gated fan-out (collapse)
# ===========================================================================
class TestCollapseFanout:
    # the residual predicate is opaque to the CBO (default selectivity), so
    # the estimate keeps the 2-lane fan-out while the actual join output is
    # a few thousand rows — the payoff gate must collapse the lanes
    SQL = ("SELECT b.v, SUM(b.k) AS sv FROM big b JOIN dim d "
           "ON b.k = d.k WHERE b.k + d.name < 20 GROUP BY b.v")
    CFG = {"broadcast_threshold_rows": 0}

    def test_collapse_parity_and_event(self, wh):
        r_on, r_off = run_pair(wh, self.SQL, self.CFG, self.CFG)
        assert rowset(r_on) == rowset(r_off)
        ev = [e for e in r_on.info["adaptive"]
              if e["kind"] == "collapsed_fanout"]
        assert ev, r_on.info.get("adaptive")
        assert ev[0]["rows"] < ev[0]["threshold"] <= ev[0]["est_rows"]

    def test_high_volume_fanout_kept(self, wh):
        r_on, r_off = run_pair(
            wh, "SELECT b.v, SUM(b.k) AS sv FROM big b JOIN dim d "
                "ON b.k = d.k GROUP BY b.v", self.CFG, self.CFG)
        assert rowset(r_on) == rowset(r_off)
        assert "collapsed_fanout" not in kinds(r_on)


# ===========================================================================
# co-partition shuffle elision
# ===========================================================================
class TestCopartitionElision:
    CFG = {"broadcast_threshold_rows": 0}
    SQL = ("SELECT b.k, SUM(b.v) AS sv FROM big b JOIN dim d "
           "ON b.k = d.k GROUP BY b.k")

    def test_elision_parity_and_event(self, wh):
        r_on, r_off = run_pair(
            wh, self.SQL, self.CFG,
            {**self.CFG, "adaptive.elide_copartition": False})
        assert rowset(r_on) == rowset(r_off)
        ev = [e for e in r_on.info["adaptive"]
              if e["kind"] == "elided_shuffle"]
        assert ev and ev[0]["at"] == "compile"
        assert set(ev[0]["join_keys"]) <= set(ev[0]["group_keys"])

    def test_no_elision_when_keys_not_covered(self, wh):
        # GROUP BY b.v does not cover the join keys: groups span lanes, so
        # the aggregate must keep its own shuffle hop
        r_on, _ = run_pair(
            wh, "SELECT b.v, SUM(b.k) AS sv FROM big b JOIN dim d "
                "ON b.k = d.k GROUP BY b.v", self.CFG, self.CFG)
        assert "elided_shuffle" not in kinds(r_on)

    def test_elision_config_off(self, wh):
        s = wh.session(**{**AUTO, **self.CFG,
                          "adaptive.elide_copartition": False})
        r = s.execute(self.SQL)
        assert "elided_shuffle" not in kinds(r)


# ===========================================================================
# straggler speculation
# ===========================================================================
@pytest.fixture()
def lockdep_on(monkeypatch):
    monkeypatch.setenv("REPRO_LOCKDEP", "1")
    lockdep.reset()
    yield
    lockdep.reset()


def _compile(wh, session, sql):
    from repro.core.optimizer.rules import Optimizer
    from repro.core.runtime.dag import compile_dag
    from repro.core.sql.binder import Binder
    from repro.core.sql.parser import parse

    plan = Binder(wh.hms).bind(parse(sql))
    plan = Optimizer(wh.hms).optimize(plan)
    return compile_dag(session._expand_shuffle(plan, session.config))


class TestSpeculation:
    SQL = "SELECT k, SUM(v) AS sv FROM big GROUP BY k"

    def _run(self, wh, delays, events):
        from repro.core.runtime.adaptive import AdaptiveManager
        from repro.core.runtime.dag import DAGScheduler

        s = wh.session(**{"shuffle.partitions": 2})
        dag = _compile(wh, s, self.SQL)
        cfg = dict(s.config)
        cfg.update({"adaptive.speculation": True,
                    "adaptive.straggler_min_s": 0.1,
                    "adaptive.straggler_factor": 2.0})
        adaptive = AdaptiveManager(cfg, events=events)
        clones = sorted(vid for vid, v in dag.vertices.items()
                        if "Aggregate" in v.plan.describe() and v.deps)
        sched = DAGScheduler(
            adaptive=adaptive,
            injected_delays={clones[i]: d for i, d in delays.items()})
        ctx = s._make_ctx({**s.config, "result_cache": False})
        return sched.execute(dag, ctx)

    def test_straggler_swap_stress_under_lockdep(self, wh, lockdep_on):
        """Repeated first-finisher swaps with the lock-order sanitizer on:
        a lock-order inversion between the manager, the swappable source,
        and the exchanges raises from lockdep and fails the test."""
        s_ref = wh.session(**{"shuffle.partitions": 2,
                              "result_cache": False})
        expect = rowset(s_ref.execute(self.SQL))
        swaps = 0
        for round_ in range(3):
            events = []
            out = self._run(wh, {round_ % 2: 1.2}, events)
            got = sorted(zip(*[out.cols[c].tolist()
                               for c in out.column_names]))
            assert got == expect, f"round {round_} parity"
            ks = [e["kind"] for e in events]
            assert "speculated" in ks, events
            swaps += ks.count("speculation_swap")
        assert swaps >= 1, "no clone ever won a swap across 3 rounds"

    def test_speculation_off_by_default(self, wh):
        r_on, _ = run_pair(
            wh, self.SQL)
        assert "speculated" not in kinds(r_on)


# ===========================================================================
# surfacing: poll() and EXPLAIN ANALYZE
# ===========================================================================
class TestSurfacing:
    def test_explain_analyze_shows_adaptive_log(self, wh):
        s = wh.session(**AUTO)
        r = s.execute("EXPLAIN ANALYZE SELECT k, SUM(v) AS sv "
                      "FROM skewed GROUP BY k")
        text = "\n".join(str(x) for x in r.batch.cols["plan"])
        assert "adaptive decisions:" in text
        assert "lane_split" in text

    def test_poll_surfaces_adaptive_events(self, wh):
        conn = db.connect(warehouse=wh, **AUTO)
        try:
            h = conn.execute_async(
                "SELECT k, SUM(v) AS sv FROM skewed GROUP BY k")
            h.result(timeout=60)
            events = h.poll().get("adaptive") or []
            assert any(e["kind"] == "lane_split" for e in events), events
        finally:
            conn.close()


# ===========================================================================
# resilience: adaptive under spill pressure
# ===========================================================================
class TestUnderPressure:
    def test_split_parity_with_tiny_buffers(self, wh):
        # force lane spill while the hot lane splits mid-stream
        cfg = {"exchange.buffer_rows": 4096}
        r_on, r_off = run_pair(
            wh, "SELECT k, SUM(v) AS sv FROM skewed GROUP BY k", cfg, cfg)
        assert rowset(r_on) == rowset(r_off)
