"""Serving tier (PR 6): shared scans, serving result cache, sharded
admission, serve-without-admission, and the DROP-during-scan race.

Covers: result parity between attached and fresh scans, byte-bounded LRFU
eviction and write-ID invalidation of the serving result cache, cache hits
served without a WLM slot while the pool is saturated, sharded-admission
stress (no lost wakeups; kill triggers still fire), DROP TABLE racing an
in-flight scan, and a 32-client mixed-workload concurrency smoke (the CI
deadlock-guard step).
"""
import threading
import time

import numpy as np
import pytest

import repro.api as db
from repro.core.runtime.wlm import QueryKilledError

SERVING_OFF = {"serving.shared_scans": False, "serving.result_cache": False}


def wait_for(cond, timeout=10.0, interval=0.01, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out after {timeout}s waiting for {what}")


@pytest.fixture()
def conn(tmp_path):
    c = db.connect(str(tmp_path / "wh"))
    cur = c.cursor()
    cur.execute("CREATE TABLE dim (k INT, grp INT, w DOUBLE)")
    cur.execute("CREATE TABLE fact (fk INT, v INT)")
    rows = ", ".join(f"({i}, {i % 7}, {i * 0.5})" for i in range(60))
    cur.execute(f"INSERT INTO dim VALUES {rows}")
    rng = np.random.default_rng(11)
    fk = rng.integers(0, 60, 4000)
    v = rng.integers(0, 1000, 4000)
    rows = ", ".join(f"({int(a)}, {int(b)})" for a, b in zip(fk, v))
    cur.execute(f"INSERT INTO fact VALUES {rows}")
    yield c
    c.close()


# ===========================================================================
# shared scans
# ===========================================================================
def _compile(session, sql):
    from repro.core.optimizer.rules import Optimizer
    from repro.core.runtime.dag import compile_dag
    from repro.core.sql.binder import Binder
    from repro.core.sql.parser import parse

    plan = Optimizer(session.hms).optimize(
        Binder(session.hms).bind(parse(sql)))
    return compile_dag(plan)


def test_attached_scan_parity_with_fresh(conn):
    """A query attaching to an in-flight scan's exchange produces exactly
    the rows a fresh (serving-off) scan produces.  Deterministic: the
    producer's root vertex is delayed so its published scan entry is
    guaranteed live while the consumer DAG runs."""
    from repro.core.runtime.dag import DAGScheduler

    wh = conn.warehouse
    s = conn.session
    cfg = {**s.config, "result_cache": False, "semijoin_reduction": False}
    q1 = ("SELECT grp, SUM(v) AS s FROM fact, dim WHERE fk = k"
          " GROUP BY grp ORDER BY grp")
    q2 = ("SELECT grp, COUNT(v) AS c FROM fact, dim WHERE fk = k"
          " GROUP BY grp ORDER BY grp")
    dag1, dag2 = _compile(s, q1), _compile(s, q2)

    producer_out, errs = [], []

    def produce():
        try:
            sched = DAGScheduler(injected_delays={dag1.root: 1.5})
            producer_out.append(sched.execute(dag1, s._make_ctx(cfg)))
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errs.append(exc)

    t = threading.Thread(target=produce)
    t.start()
    wait_for(lambda: wh.shared_scans.stats_snapshot()["live_entries"] > 0,
             what="producer to publish its scan exchanges")

    before = wh.shared_scans.stats_snapshot()["attached"]
    attached = DAGScheduler().execute(dag2, s._make_ctx(cfg))
    assert wh.shared_scans.stats_snapshot()["attached"] > before, \
        "consumer never attached to the live scan"

    fresh_ctx = s._make_ctx(cfg)
    fresh_ctx.shared_scans = None
    fresh = DAGScheduler().execute(dag2, fresh_ctx)
    assert attached.to_rows() == fresh.to_rows()

    t.join(timeout=30)
    assert not errs, errs
    # the producer's own result is unaffected by having been shared
    off_ctx = s._make_ctx(cfg)
    off_ctx.shared_scans = None
    assert producer_out[0].to_rows() == \
        DAGScheduler().execute(dag1, off_ctx).to_rows()
    wait_for(lambda: wh.shared_scans.stats_snapshot()["live_entries"] == 0,
             what="all published entries to retire")


def test_shared_scan_disabled_never_publishes(conn):
    wh = conn.warehouse
    off = db.connect(warehouse=wh, **SERVING_OFF)
    off.execute("SELECT grp, SUM(v) AS s FROM fact, dim WHERE fk = k"
                " GROUP BY grp").fetchall()
    assert wh.serving_stats()["shared_scans"]["published"] == 0
    off.close()


def test_snapshot_difference_prevents_sharing(conn):
    """A write between two executions changes the write-ID state, so the
    second query's scan key misses the registry instead of reading stale
    retained chunks."""
    wh = conn.warehouse
    on = db.connect(warehouse=wh, semijoin_reduction=False,
                    result_cache=False, **{"debug_vertex_delay_s": 0.1})
    q = ("SELECT grp, SUM(v) AS s FROM fact, dim WHERE fk = k"
         " GROUP BY grp ORDER BY grp")
    h1 = on.execute_async(q)
    r1 = h1.result().fetchall()
    on.execute("INSERT INTO fact VALUES (0, 100000)")
    r2 = on.execute(q).fetchall()
    assert r1 != r2  # the insert must be visible to the second run
    on.close()


# ===========================================================================
# serving result cache
# ===========================================================================
def test_result_cache_invalidated_on_write(conn):
    q = "SELECT SUM(v) AS s FROM fact"
    first = conn.execute(q).fetchall()
    again = conn.execute(q).fetchall()
    assert first == again
    assert conn.server_stats()["result_cache"]["hits"] >= 1
    conn.execute("INSERT INTO fact VALUES (1, 123456)")
    bumped = conn.execute(q).fetchall()
    assert bumped[0][0] == first[0][0] + 123456


def test_result_cache_byte_bound_lrfu_eviction(tmp_path):
    from repro.core.serving import ResultCacheServer
    from repro.core.session import Warehouse

    wh = Warehouse(str(tmp_path / "wh"), result_cache_bytes=2 << 10)
    assert isinstance(wh.result_cache, ResultCacheServer)
    s = wh.session()
    s.execute("CREATE TABLE t (a INT)")
    s.execute("INSERT INTO t VALUES " +
              ", ".join(f"({i})" for i in range(400)))
    # each distinct window caches a ~480-byte result; ten of them overflow
    # the 2 KiB budget, forcing LRFU victims out
    for lo in range(0, 300, 30):
        s.execute(f"SELECT a FROM t WHERE a >= {lo} AND a < {lo + 60}")
    stats = wh.result_cache.stats_snapshot()
    assert stats["evictions"] > 0
    assert stats["bytes_used"] <= 2 << 10
    wh.close()


def test_cache_hit_served_without_admission(conn):
    """With the only pool slot occupied by a slow query, a repeated
    (cached) query completes without ever taking a WLM slot."""
    wh = conn.warehouse
    s = conn.session
    q = "SELECT SUM(v) AS s FROM fact"
    warm = conn.execute(q).fetchall()  # fill the cache pre-plan
    for ddl in [
        "CREATE RESOURCE PLAN serve",
        "CREATE POOL serve.only WITH alloc_fraction=1.0,"
        " query_parallelism=1",
        "ALTER PLAN serve SET DEFAULT POOL = only",
        "ALTER RESOURCE PLAN serve ENABLE ACTIVATE",
    ]:
        s.execute(ddl)
    slow_conn = db.connect(warehouse=wh, result_cache=False,
                           **{"debug_vertex_delay_s": 0.5})
    slow = slow_conn.execute_async(
        "SELECT grp, SUM(v) AS s FROM fact, dim WHERE fk = k GROUP BY grp")
    wait_for(lambda: wh.wlm.queue_depths().get("only", 0) == 0
             and slow.poll()["state"] in ("ADMITTED", "RUNNING"),
             what="slow query to occupy the pool")
    h = conn.execute_async(q)
    res = h.result(timeout=5).fetchall()  # must NOT queue behind `slow`
    assert res == warm
    assert h.info.get("admission_skipped") is True
    assert h.info.get("cache_hit") is True
    slow.result(timeout=30)
    slow_conn.close()


# ===========================================================================
# sharded admission
# ===========================================================================
def test_sharded_admission_stress_no_lost_wakeups(conn):
    """Many more async queries than slots across two pools: every one is
    eventually admitted and completes (no lost wakeups across shards)."""
    s = conn.session
    for ddl in [
        "CREATE RESOURCE PLAN shard",
        "CREATE POOL shard.a WITH alloc_fraction=0.5, query_parallelism=2",
        "CREATE POOL shard.b WITH alloc_fraction=0.5, query_parallelism=2",
        "CREATE USER MAPPING ua IN shard TO a",
        "CREATE USER MAPPING ub IN shard TO b",
        "ALTER PLAN shard SET DEFAULT POOL = a",
        "ALTER RESOURCE PLAN shard ENABLE ACTIVATE",
    ]:
        s.execute(ddl)
    wh = conn.warehouse
    conns = [db.connect(warehouse=wh, user=u, result_cache=False)
             for u in ("ua", "ub") for _ in range(2)]
    handles = []
    for i in range(40):
        c = conns[i % len(conns)]
        handles.append(c.execute_async(
            f"SELECT COUNT(*) AS n FROM fact WHERE v >= {i % 3}"))
    for h in handles:
        assert h.result(timeout=60).fetchall()[0][0] > 0
    assert all(d == 0 for d in wh.wlm.queue_depths().values())
    for c in conns:
        c.close()


def test_kill_trigger_fires_with_sharded_admission(conn):
    s = conn.session
    for ddl in [
        "CREATE RESOURCE PLAN reap",
        "CREATE POOL reap.p WITH alloc_fraction=1.0, query_parallelism=4",
        "ALTER PLAN reap SET DEFAULT POOL = p",
        "ALTER RESOURCE PLAN reap ENABLE ACTIVATE",
    ]:
        s.execute(ddl)
    wlm = conn.warehouse.wlm
    wlm.create_rule("reap", "reaper", "rows_produced", 100, "kill", None)
    wlm.activate("reap")
    slot = wlm.admit("qk")
    with pytest.raises(QueryKilledError):
        wlm.update_metrics("qk", rows_produced=1000)
    assert slot.killed
    wlm.release("qk")


# ===========================================================================
# DROP TABLE racing an in-flight scan
# ===========================================================================
def test_drop_table_during_scan_fails_cleanly_or_completes(conn):
    """DROP TABLE while a scan of the same table streams: the query either
    completes on its snapshot or fails with the explicit dropped-during-scan
    error — never a partial result or a bare file error."""
    wh = conn.warehouse
    total = conn.execute("SELECT COUNT(*) AS n FROM fact").fetchall()[0][0]
    slow = db.connect(warehouse=wh, result_cache=False,
                      **{"serving.shared_scans": False,
                         "debug_vertex_delay_s": 0.3})
    h = slow.execute_async(
        "SELECT grp, COUNT(v) AS c FROM fact, dim WHERE fk = k GROUP BY grp")
    wait_for(lambda: h.poll()["state"] == "RUNNING",
             what="scan to start")
    conn.execute("DROP TABLE fact")
    try:
        rows = h.result(timeout=30).fetchall()
    except db.Error as exc:
        assert "dropped during" in str(exc) or "fact" in str(exc)
    else:
        # completed on its snapshot: counts must cover every fact row
        assert sum(c for _, c in rows) == total
    slow.close()


def test_drop_table_invalidates_shared_scan_registry(conn):
    wh = conn.warehouse
    wh.shared_scans.publish(("key",), "dim", object())
    conn.execute("DROP TABLE dim")
    assert wh.shared_scans.attach(("key",)) is None
    assert wh.serving_stats()["shared_scans"]["invalidated"] >= 1


# ===========================================================================
# concurrency smoke (CI runs this with the SIGALRM deadlock guard)
# ===========================================================================
def test_concurrency_smoke_32_clients(tmp_path):
    """32 concurrent clients, seeded mixed repeated/unique workload:
    everything completes, with nonzero shared-scan and result-cache hits."""
    from repro.core.session import Warehouse

    wh = Warehouse(str(tmp_path / "wh"), query_workers=32)
    base = db.connect(warehouse=wh)
    cur = base.cursor()
    cur.execute("CREATE TABLE d (k INT, yr INT, w DOUBLE)")
    cur.execute("INSERT INTO d VALUES " +
                ", ".join(f"({i}, {1992 + i % 6}, {i * 0.5})"
                          for i in range(48)))
    cur.execute("CREATE TABLE f (fk INT, rev INT)")
    rng = np.random.default_rng(3)
    fk = rng.integers(0, 48, 6000)
    rev = rng.integers(1, 500, 6000)
    cur.execute("INSERT INTO f VALUES " + ", ".join(
        f"({int(a)}, {int(b)})" for a, b in zip(fk, rev)))

    repeated = ["SELECT yr, SUM(rev) AS s FROM f, d WHERE fk = k GROUP BY yr",
                "SELECT COUNT(*) AS n FROM f"]

    def unique_sql(cid, j):
        # unique filters live on non-join-key dim columns: each query is
        # distinct (no result-cache absorption) and no predicate transits
        # onto the fact side, so the fact-scan vertex key stays identical
        # and overlapping executions attach to each other's scans
        n = cid * 4 + j
        return (f"SELECT yr, SUM(rev) AS s FROM f, d WHERE fk = k"
                f" AND yr >= {1992 + n % 5} AND w >= {n * 0.01:.2f}"
                f" GROUP BY yr")

    errors = []

    def client(cid):
        try:
            c = db.connect(warehouse=wh, semijoin_reduction=False,
                           **{"debug_vertex_delay_s": 0.05})
            r = np.random.default_rng(cid)
            for j in range(4):
                if r.uniform() < 0.5:
                    sql = repeated[int(r.integers(len(repeated)))]
                else:
                    sql = unique_sql(cid, j)
                rows = c.execute(sql).fetchall()
                assert rows
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errors.append((cid, exc))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "client threads deadlocked"
    assert not errors, errors[:3]
    stats = wh.serving_stats()
    assert stats["result_cache"]["hits"] > 0
    assert stats["shared_scans"]["attached"] > 0
    base.close()
    wh.close()
