"""LLAP cache + I/O elevator (§5.1), stripe files, stats sketches."""
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bloomfilter import BloomFilter
from repro.core.runtime.lrfu import LRFUPolicy
from repro.core.runtime.vector import VectorBatch
from repro.core.stats import HyperLogLogPP, compute_column_stats
from repro.core.storage import (
    SargPredicate,
    read_file_meta,
    write_stripe_file,
)


def test_stripe_file_roundtrip_and_sarg_skip(tmp_path):
    from repro.core.runtime.llap import LlapDaemon, LlapIO

    n = 40_000
    batch = VectorBatch({
        "k": np.arange(n, dtype=np.int64),
        "v": np.linspace(0, 1, n),
    })
    path = str(tmp_path / "f.tahoe")
    meta = write_stripe_file(path, batch, stripe_rows=8192, bloom_columns=["k"])
    assert meta.num_rows == n and len(meta.stripes) == 5

    daemon = LlapDaemon(cache_bytes=64 << 20)
    io = LlapIO(daemon)
    # predicate selecting only the first stripe -> 4 stripes skipped
    m2, out = io.read_file(path, ["k", "v"],
                           sarg_preds=[SargPredicate("k", "<", 100)])
    assert daemon.counters["stripes_skipped"] == 4
    assert out.num_rows == 8192  # stripe granularity; row filter comes later


def test_llap_cache_hits_and_mvcc_identity(tmp_path):
    from repro.core.runtime.llap import LlapDaemon, LlapIO

    batch = VectorBatch({"x": np.arange(10_000)})
    p1 = str(tmp_path / "a.tahoe")
    write_stripe_file(p1, batch)
    daemon = LlapDaemon()
    io = LlapIO(daemon)
    io.read_file(p1, ["x"])
    misses = daemon.counters["cache_misses"]
    io.read_file(p1, ["x"])
    assert daemon.counters["cache_misses"] == misses  # warm
    assert daemon.counters["cache_hits"] > 0
    # a different file with identical rows has a different content file_id:
    # cache entries never collide across file versions (MVCC at file level)
    p2 = str(tmp_path / "b.tahoe")
    write_stripe_file(p2, VectorBatch({"x": np.arange(10_000) + 1}))
    io.read_file(p2, ["x"])
    assert daemon.counters["cache_misses"] > misses


def test_llap_eviction_under_pressure(tmp_path):
    from repro.core.runtime.llap import LlapDaemon, LlapIO

    daemon = LlapDaemon(cache_bytes=200_000)  # tiny pool
    io = LlapIO(daemon)
    for i in range(6):
        p = str(tmp_path / f"f{i}.tahoe")
        # distinct content per file (identical content shares a file_id
        # and deduplicates in the cache — by design)
        write_stripe_file(p, VectorBatch({"x": np.arange(10_000) * (i + 1)}))
        io.read_file(p, ["x"])
    used, cap = daemon.cache_usage()
    assert used <= cap
    assert daemon.counters["evictions"] > 0


def test_lrfu_policy_prefers_frequent():
    pol = LRFUPolicy(lam=0.1)
    for _ in range(5):
        pol.on_access("hot")
    pol.on_access("cold")
    pol.on_access("hot")
    assert pol.victim() == "cold"


def test_hll_accuracy_and_merge():
    h1, h2 = HyperLogLogPP(12), HyperLogLogPP(12)
    for i in range(3000):
        h1.add(i)
    for i in range(2000, 5000):
        h2.add(i)
    merged = h1.merge(h2)
    assert abs(merged.cardinality() - 5000) / 5000 < 0.05
    # serialization roundtrip
    again = HyperLogLogPP.deserialize(merged.serialize())
    assert again.cardinality() == merged.cardinality()


def test_column_stats_additive(star_schema):
    st_ = star_schema.hms.get_stats("store_sales")
    assert st_.row_count == 8000
    cs = st_.columns["ss_customer_sk"]
    assert abs(cs.ndv - 300) / 300 < 0.06


@settings(max_examples=25, deadline=None)
@given(members=st.sets(st.integers(0, 10_000), min_size=1, max_size=300),
       probes=st.lists(st.integers(0, 10_000), min_size=1, max_size=100))
def test_property_bloom_no_false_negatives(members, probes):
    bf = BloomFilter.for_expected(len(members))
    bf.add(np.array(sorted(members)))
    got = bf.might_contain(np.array(probes))
    for p, g in zip(probes, got):
        if p in members:
            assert g  # bloom filters never produce false negatives
