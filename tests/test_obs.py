"""Observability layer (PR 10): per-query tracing, the warehouse metrics
registry, the always-on query log, and the trace-backed EXPLAIN ANALYZE.

Covers the acceptance contract of the obs subsystem:

  * tracing off is *free*: hot-path helpers return the shared NOOP_SPAN
    singleton (identity-checked — zero span allocations) and queries carry
    no QueryTrace;
  * tracing on records one span per pipeline stage and one vertex record
    per DAG vertex, with monotone timestamps and proper nesting;
  * the Chrome export validates (ph/ts/pid/tid present, B/E balanced,
    per-tid monotone) through ``repro.analysis.trace_check``;
  * ``poll()`` / ``server_stats()`` keep their historical dict shapes but
    now derive from the MetricsRegistry;
  * the query log is a bounded ring (oldest evicts first);
  * cache-served results report the same ``stage_times_ms`` keys as
    executed ones (satellite a).
"""
import glob
import json
import os

import numpy as np
import pytest

import repro.api as db
from repro.analysis.trace_check import validate_chrome_trace
from repro.core.obs import (NOOP_SPAN, MetricsRegistry, QueryLog, QueryTrace,
                            emit_event, make_span, tracing_enabled)

TRACED = {"obs.tracing": True}


@pytest.fixture()
def wh_dir(tmp_path):
    return str(tmp_path / "wh")


def _load_events(conn):
    conn.execute("CREATE TABLE ev (k BIGINT, grp BIGINT, val DOUBLE)")
    conn.execute(
        "INSERT INTO ev VALUES " + ", ".join(
            f"({i}, {i % 7}, {float(i) / 3:.4f})" for i in range(300)))


# ===========================================================================
# tracing off: no allocations, no traces
# ===========================================================================
class TestTracingOff:
    def test_make_span_returns_noop_singleton(self):
        s1 = make_span(None, "stage:parse", "stage")
        s2 = make_span(None, "vertex:v1", "vertex")
        assert s1 is NOOP_SPAN and s2 is NOOP_SPAN

    def test_emit_event_is_noop_without_trace(self):
        emit_event(None, "adaptive:skew", "adaptive", vid="v1")  # no raise

    def test_tracing_enabled_resolution(self, monkeypatch):
        monkeypatch.delenv("REPRO_OBS_TRACING", raising=False)
        assert tracing_enabled({"obs.tracing": False}) is False
        assert tracing_enabled({"obs.tracing": True}) is True
        monkeypatch.setenv("REPRO_OBS_TRACING", "1")
        assert tracing_enabled({"obs.tracing": False}) is True
        monkeypatch.setenv("REPRO_OBS_TRACING", "0")
        assert tracing_enabled({"obs.tracing": False}) is False

    def test_untraced_query_allocates_no_trace(self, wh_dir):
        with db.connect(wh_dir) as conn:
            _load_events(conn)
            h = conn.execute_async("SELECT grp, SUM(val) FROM ev GROUP BY grp")
            h.result()
            assert h._task.trace is None
            with pytest.raises(RuntimeError, match="tracing off"):
                h.trace()

    def test_query_log_records_even_untraced(self, wh_dir):
        with db.connect(wh_dir) as conn:
            _load_events(conn)
            conn.execute("SELECT COUNT(*) FROM ev").fetchall()
            log = conn.query_log()
            assert log, "query log must be always-on"
            assert {"qid", "sql", "status", "wall_ms"} <= set(log[-1])
            assert log[-1]["status"] == "SUCCEEDED"


# ===========================================================================
# tracing on: spans, vertices, Chrome export
# ===========================================================================
class TestTracedQuery:
    def test_stage_spans_and_vertex_records(self, wh_dir):
        # engine="ref": aggregate kernels only route when engine != auto
        with db.connect(wh_dir, engine="ref", **TRACED) as conn:
            _load_events(conn)
            h = conn.execute_async(
                "SELECT grp, SUM(k), COUNT(*) FROM ev "
                "WHERE k > 10 GROUP BY grp")
            h.result()
            trace = h._task.trace
            assert trace is not None
            summ = trace.summary()
            # every pipeline stage that ran shows up as a stage span
            for stage in ("parse", "bind", "optimize", "compile", "execute"):
                assert stage in summ["stages_ms"], summ["stages_ms"]
            # one vertex record per DAG vertex, wall split into sub-phases
            done = h.poll()
            assert len(summ["vertices"]) == done["vertices_total"]
            for vid, v in summ["vertices"].items():
                total = v["total_ms"]
                parts = (v["compute_ms"] + v["exchange_wait_ms"]
                         + v["spill_io_ms"])
                assert total >= 0 and parts <= total + 0.01, (vid, v)
            assert summ["kernel_dispatches"], "kernels must be counted"

    def test_chrome_export_validates(self, wh_dir):
        with db.connect(wh_dir, **TRACED) as conn:
            _load_events(conn)
            h = conn.execute_async(
                "SELECT grp, AVG(val) FROM ev GROUP BY grp")
            h.result()
            data = h.trace()
            assert validate_chrome_trace(data) == []
            events = data["traceEvents"]
            # balanced B/E with monotone, non-negative timestamps per tid
            opens = {}
            for ev in events:
                assert {"ph", "ts", "pid", "tid", "name"} <= set(ev)
                if ev["ph"] == "B":
                    opens.setdefault(ev["tid"], []).append(ev)
                elif ev["ph"] == "E":
                    assert opens[ev["tid"]], "E without open B"
                    b = opens[ev["tid"]].pop()
                    assert ev["ts"] >= b["ts"] >= 0
            assert all(not stack for stack in opens.values())

    def test_export_trace_roundtrip(self, wh_dir, tmp_path):
        with db.connect(wh_dir, **TRACED) as conn:
            _load_events(conn)
            h = conn.execute_async("SELECT COUNT(*) FROM ev")
            h.result()
            path = str(tmp_path / "trace.json")
            assert conn.export_trace(h.query_id, path) == path
            with open(path) as f:
                assert validate_chrome_trace(json.load(f)) == []
            with pytest.raises(KeyError):
                conn.export_trace("q999999", str(tmp_path / "x.json"))

    def test_stage_spans_nest_and_order(self):
        tr = QueryTrace("q1", "SELECT 1")
        with tr.span("stage:execute", "stage"):
            with tr.span("wlm:admission_wait", "wlm"):
                pass
        data = tr.to_chrome()
        rows = [(e["ph"], e["name"], e["ts"]) for e in data["traceEvents"]
                if e["ph"] in "BE"]
        names = [r[1] for r in rows]
        # inner span closes before the outer one
        assert names.index("wlm:admission_wait") \
            < names.index("stage:execute", 1) \
            or names == ["stage:execute", "wlm:admission_wait",
                         "wlm:admission_wait", "stage:execute"]
        ts = [r[2] for r in rows]
        assert ts == sorted(ts)


# ===========================================================================
# metrics registry as the single stats source
# ===========================================================================
class TestMetrics:
    def test_registry_primitives(self):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        reg.inc("c", 2)
        reg.gauge("g", lambda: {"pool": 3})
        reg.observe("h_ms", 12.5)
        snap = reg.snapshot()
        assert snap["counters"]["c"] == 3
        assert snap["gauges"]["g"] == {"pool": 3}
        assert snap["histograms"]["h_ms"]["count"] == 1

    def test_serving_stats_shape_preserved_and_registry_backed(self, wh_dir):
        with db.connect(wh_dir) as conn:
            _load_events(conn)
            sql = "SELECT grp, COUNT(*) FROM ev GROUP BY grp"
            conn.execute(sql).fetchall()
            conn.execute(sql).fetchall()
            stats = conn.server_stats()
            # historical shape
            assert {"result_cache", "shared_scans",
                    "admission_queues"} <= set(stats)
            rc = stats["result_cache"]
            assert {"hits", "misses", "evictions", "fills"} <= set(rc)
            assert rc["hits"] >= 1
            # same numbers flow from the registry snapshot
            counters = conn.metrics()["counters"]
            assert counters["serving.result_cache.hits"] == rc["hits"]
            assert counters["serving.result_cache.misses"] == rc["misses"]
            sc = stats["shared_scans"]
            assert counters["serving.shared_scans.published"] \
                == sc["published"]

    def test_wlm_counters_in_registry(self, wh_dir):
        with db.connect(wh_dir) as conn:
            _load_events(conn)
            for ddl in ("CREATE RESOURCE PLAN obsplan",
                        "CREATE POOL obsplan.bi WITH alloc_fraction=1.0, "
                        "query_parallelism=4",
                        "ALTER PLAN obsplan SET DEFAULT POOL = bi",
                        "ALTER RESOURCE PLAN obsplan ENABLE ACTIVATE"):
                conn.execute(ddl)
            conn.execute_async("SELECT COUNT(*) FROM ev").result()
            m = conn.metrics()
            assert m["counters"].get("wlm.admitted", 0) >= 1
            assert "wlm.queue_depths" in m["gauges"]

    def test_kernel_dispatch_counts_surface(self, wh_dir):
        with db.connect(wh_dir, engine="ref") as conn:
            _load_events(conn)
            conn.execute("SELECT grp, SUM(k) FROM ev GROUP BY grp")
            m = conn.metrics()
            assert any(k.startswith("kernels.dispatch.")
                       for k in m["counters"])

    def test_query_outcome_counters(self, wh_dir):
        with db.connect(wh_dir) as conn:
            _load_events(conn)
            conn.execute("SELECT COUNT(*) FROM ev").fetchall()
            with pytest.raises(db.Error):
                conn.execute("SELECT nope FROM ev").fetchall()
            c = conn.metrics()["counters"]
            assert c.get("query.succeeded", 0) >= 1
            assert c.get("query.failed", 0) >= 1


# ===========================================================================
# query log ring
# ===========================================================================
class TestQueryLog:
    def test_ring_bounds_and_eviction(self):
        log = QueryLog(capacity=4)
        for i in range(10):
            log.record({"qid": f"q{i}"})
        assert len(log) == 4
        assert [e["qid"] for e in log.entries()] == ["q6", "q7", "q8", "q9"]
        assert [e["qid"] for e in log.entries(limit=2)] == ["q8", "q9"]

    def test_entries_are_copies(self):
        log = QueryLog(capacity=2)
        log.record({"qid": "q0"})
        log.entries()[0]["qid"] = "mutated"
        assert log.entries()[0]["qid"] == "q0"

    def test_failed_and_cancelled_logged(self, wh_dir):
        with db.connect(wh_dir) as conn:
            _load_events(conn)
            with pytest.raises(db.Error):
                conn.execute("SELECT nope FROM ev").fetchall()
            statuses = {e["status"] for e in conn.query_log()}
            assert "FAILED" in statuses
            failed = [e for e in conn.query_log()
                      if e["status"] == "FAILED"][-1]
            assert failed["error"]


# ===========================================================================
# satellite (a): cache-hit stage_times_ms parity
# ===========================================================================
class TestCacheHitStageParity:
    def test_same_keys_zeroed_post_probe(self, wh_dir):
        with db.connect(wh_dir) as conn:
            _load_events(conn)
            sql = "SELECT grp, MAX(val) FROM ev GROUP BY grp"
            miss = conn.execute(sql).info
            hit = conn.execute(sql).info
            assert hit["cache_hit"] is True
            assert hit.get("admission_skipped") is True
            assert set(hit["stage_times_ms"]) == set(miss["stage_times_ms"])
            assert hit["stage_times_ms"]["execute"] == 0.0
            assert hit["stage_times_ms"]["compile"] == 0.0
            assert hit["stage_times_ms"]["parse"] > 0.0


# ===========================================================================
# trace-backed EXPLAIN ANALYZE
# ===========================================================================
class TestExplainAnalyze:
    def test_vertex_breakdown_and_events(self, wh_dir):
        with db.connect(wh_dir, engine="ref") as conn:
            _load_events(conn)
            cur = conn.execute(
                "EXPLAIN ANALYZE SELECT grp, SUM(k) FROM ev "
                "WHERE k > 5 GROUP BY grp")
            text = "\n".join(r[0] for r in cur.fetchall())
            assert "stage timings:" in text
            assert "vertex breakdown:" in text
            assert "compute=" in text and "exchange_wait=" in text \
                and "spill_io=" in text
            assert "kernel dispatches:" in text

    def test_analyze_forces_tracing_without_session_flag(self, wh_dir):
        # session tracing off: ANALYZE still gets a trace-backed report
        with db.connect(wh_dir) as conn:
            _load_events(conn)
            cur = conn.execute("EXPLAIN ANALYZE SELECT COUNT(*) FROM ev")
            text = "\n".join(r[0] for r in cur.fetchall())
            assert "vertex breakdown:" in text
