"""Per-architecture smoke tests (reduced configs, one train + decode step on
CPU) and decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    ARCH_IDS,
    ShapeConfig,
    get_config,
    load_all,
    reduced_config,
    supported_shapes,
)
from repro.models import model as M
from repro.train.optimizer import adamw_init
from repro.train.steps import make_serve_step, make_train_step, materialize_batch

load_all()
SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_and_decode(arch):
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = materialize_batch(cfg, SMOKE_SHAPE, key)["batch"]
    train_step = jax.jit(make_train_step(cfg))
    p2, opt2, metrics = train_step(params, adamw_init(params), batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss"
    assert 1.0 < loss < 20.0
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0

    cache = M.init_cache(cfg, 2, 64)
    serve = jax.jit(make_serve_step(cfg))
    if cfg.embedding_stub:
        tok = jnp.zeros((2, 1, cfg.d_model), jnp.bfloat16)
    else:
        tok = jnp.zeros((2,), jnp.int32)
    nt, cache2 = serve(params, cache, tok, jnp.int32(0))
    assert nt.shape == (2,)
    assert not any(bool(jnp.any(jnp.isnan(x))) for x in
                   jax.tree.leaves(cache2) if x.dtype.kind == "f")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_mirror_params(arch):
    cfg = reduced_config(get_config(arch))
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    axes = M.param_axes(cfg)
    from repro.launch.sharding import _is_axes_leaf

    p_leaves = jax.tree.leaves(params)
    a_leaves = jax.tree.leaves(axes, is_leaf=_is_axes_leaf)
    assert len(p_leaves) == len(a_leaves)
    for p, a in zip(p_leaves, a_leaves):
        assert len(a) <= len(p.shape) or all(x is None for x in a[len(p.shape):])


def test_shape_support_matrix():
    counts = {a: len(supported_shapes(get_config(a))) for a in ARCH_IDS}
    # ssm/hybrid + gemma3 run long_500k; pure full-attention archs skip it
    assert counts["mamba2-130m"] == 4
    assert counts["zamba2-1.2b"] == 4
    assert counts["gemma3-27b"] == 4
    assert counts["granite-34b"] == 3
    assert sum(counts.values()) == 33


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-130m", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Prefill via repeated decode == full forward logits (last position)."""
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    B, S = 2, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full = M.forward(params, cfg, tokens, remat=False)
    cache = M.init_cache(cfg, B, S)
    for t in range(S):
        logits_t, cache = M.decode_step(params, cache, tokens[:, t],
                                        jnp.int32(t), cfg)
    np.testing.assert_allclose(
        np.array(logits_t, np.float32),
        np.array(logits_full[:, -1], np.float32),
        atol=0.15, rtol=0.15,  # bf16 accumulation differences
    )
    # argmax agreement is the serving-level contract
    assert (np.argmax(np.array(logits_t, np.float32), -1) ==
            np.argmax(np.array(logits_full[:, -1], np.float32), -1)).all()


def test_param_count_matches_analytic():
    for arch in ARCH_IDS:
        cfg = reduced_config(get_config(arch))
        params = jax.eval_shape(lambda c=cfg: M.init_params(c, jax.random.PRNGKey(0)))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / max(analytic, 1) < 0.02, \
            f"{arch}: actual {actual} vs analytic {analytic}"


def test_microbatched_train_matches_single():
    cfg = reduced_config(get_config("qwen3-14b"))
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    batch = materialize_batch(cfg, ShapeConfig("s", 16, 4, "train"), key)["batch"]
    _, _, m1 = jax.jit(make_train_step(cfg, microbatches=1))(
        params, adamw_init(params), batch)
    _, _, m2 = jax.jit(make_train_step(cfg, microbatches=2))(
        params, adamw_init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) < 5e-2
