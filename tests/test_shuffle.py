"""Partitioned shuffle service (PR 5).

Covers: hash_partition kernel parity (pallas/ref/host), the partition-parity
suite (identical results for ``shuffle.partitions`` 1 vs N across SSB
Q1-Q4, ACID merge-on-read reads, federated multi-split scans, and
DISTINCT/grouping-set aggregates), per-partition build/probe and
aggregation state observed through ``poll()`` per-lane telemetry,
skewed-key spill-and-replay identity, barrier-mode lane filtering,
EXPLAIN exchange-boundary rendering, connector statistics feeding the CBO,
and Druid sorted-scan limit pushdown.
"""
import tempfile

import numpy as np
import pytest

import repro.api as db
from repro.core.runtime.vector import VectorBatch

PART4 = {"shuffle.partitions": 4, "result_cache": False}
PART1 = {"shuffle.partitions": 1, "result_cache": False}
SHUFFLY = {"broadcast_threshold_rows": 0.0}  # force shuffle joins


def rounded(rows):
    def norm(x):
        if isinstance(x, float):
            return "NULL" if np.isnan(x) else round(x, 6)
        return x

    # stringify so NULL-filled grouping-set rows sort against typed rows
    return sorted(tuple(str(norm(x)) for x in r) for r in rows)


def assert_parity(wh, sql, extra=None, params=None):
    extra = extra or {}
    one = db.connect(warehouse=wh, **{**PART1, **extra})
    four = db.connect(warehouse=wh, **{**PART4, **extra})
    try:
        a = one.execute(sql, params).fetchall()
        b = four.execute(sql, params).fetchall()
        assert rounded(a) == rounded(b), sql
        return a
    finally:
        one.close()
        four.close()


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------
def test_hash_partition_kernel_parity():
    """pallas / ref / numpy-host paths assign identical buckets."""
    from repro.core.runtime.shuffle import partition_codes
    from repro.kernels.hash_partition.ops import hash_partition

    rng = np.random.default_rng(3)
    a = rng.integers(-5000, 5000, 8192).astype(np.int64)
    b = rng.uniform(-10, 10, 8192)
    batch = VectorBatch({"a": a, "b": b})
    for n in (2, 3, 4, 7, 8):
        pallas = np.asarray(hash_partition(
            (a.astype(np.float32), b.astype(np.float32)), n, engine="pallas"))
        ref = np.asarray(hash_partition(
            (a.astype(np.float32), b.astype(np.float32)), n, engine="ref"))
        host = partition_codes(batch, ["a", "b"], n, engine="auto")
        kern = partition_codes(batch, ["a", "b"], n, engine="ref")
        assert np.array_equal(pallas, ref)
        assert np.array_equal(host, pallas.astype(np.int64))
        assert np.array_equal(kern, host)
        # reasonable balance: no empty bucket on 8k uniform keys
        assert np.bincount(host, minlength=n).min() > 0


def test_hash_partition_equal_values_same_lane_across_dtypes():
    """int and float sides of a join key agree on the lane (and -0.0 == 0.0)."""
    from repro.core.runtime.shuffle import partition_codes

    ints = VectorBatch({"k": np.arange(-50, 50, dtype=np.int64)})
    floats = VectorBatch({"k": np.arange(-50, 50, dtype=np.float64)})
    ci = partition_codes(ints, ["k"], 5)
    cf = partition_codes(floats, ["k"], 5)
    assert np.array_equal(ci, cf)
    zeros = VectorBatch({"k": np.array([0.0, -0.0])})
    cz = partition_codes(zeros, ["k"], 7)
    assert cz[0] == cz[1]


def test_partition_codes_strings_stable():
    from repro.core.runtime.shuffle import partition_codes

    b = VectorBatch({"s": np.array(["x", "y", "x", "zz", "y"])})
    c1 = partition_codes(b, ["s"], 4)
    c2 = partition_codes(b, ["s"], 4)
    assert np.array_equal(c1, c2)
    assert c1[0] == c1[2] and c1[1] == c1[4]


# ---------------------------------------------------------------------------
# partition parity: SSB Q1-Q4
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def ssb_wh():
    from benchmarks.ssb import load_ssb
    from repro.core.session import Warehouse

    wh = Warehouse(tempfile.mkdtemp(prefix="shuffle_ssb_"))
    load_ssb(wh, scale_rows=12_000)
    yield wh
    wh.close()


@pytest.mark.parametrize("name", ["q1.1", "q2.1", "q3.1", "q4.1"])
def test_ssb_partition_parity(ssb_wh, name):
    from benchmarks.ssb import SSB_QUERIES

    assert_parity(ssb_wh, SSB_QUERIES[name], extra=SHUFFLY)


def test_ssb_parity_under_forced_engines(ssb_wh):
    """The kernel-dispatched bucket path (engine: ref) and the numpy host
    path produce identical lanes, hence identical results."""
    from benchmarks.ssb import SSB_QUERIES

    base = assert_parity(ssb_wh, SSB_QUERIES["q2.1"], extra=SHUFFLY)
    eng = assert_parity(ssb_wh, SSB_QUERIES["q2.1"],
                        extra={**SHUFFLY, "engine": "ref"})
    assert rounded(base) == rounded(eng)


# ---------------------------------------------------------------------------
# partition parity: ACID merge-on-read, federated splits, DISTINCT
# ---------------------------------------------------------------------------
@pytest.fixture()
def conn(tmp_path):
    c = db.connect(str(tmp_path / "wh"))
    cur = c.cursor()
    cur.execute("CREATE TABLE fact (fk INT, grp INT, v DOUBLE, s STRING)")
    cur.execute("CREATE TABLE dim (dk INT, cat STRING, weight DOUBLE)")
    rng = np.random.default_rng(11)
    fk = rng.integers(0, 60, 4000)
    grp = rng.integers(0, 17, 4000)
    v = rng.uniform(-40, 40, 4000)
    rows = ", ".join(
        f"({int(a)}, {int(g)}, {float(x):.4f}, 's{int(a) % 7}')"
        for a, g, x in zip(fk, grp, v))
    cur.execute(f"INSERT INTO fact VALUES {rows}")
    cur.execute("INSERT INTO dim VALUES " + ", ".join(
        f"({i}, 'c{i % 5}', {i * 0.5})" for i in range(55)))
    yield c
    c.close()


def test_acid_merge_on_read_partition_parity(conn):
    """Partitioned reads over a table with live delete/update deltas
    (merge-on-read) match the single-lane result."""
    cur = conn.cursor()
    cur.execute("DELETE FROM fact WHERE fk < 5")
    cur.execute("UPDATE fact SET v = v * 2 WHERE grp = 3")
    for sql in [
        "SELECT grp, COUNT(*) AS n, SUM(v) AS sv FROM fact"
        " GROUP BY grp ORDER BY grp",
        "SELECT cat, SUM(v) AS sv, MAX(v) AS mx FROM fact JOIN dim"
        " ON fk = dk GROUP BY cat ORDER BY cat",
    ]:
        assert_parity(conn.warehouse, sql, extra=SHUFFLY)


def test_federated_multisplit_partition_parity(conn):
    cur = conn.cursor()
    cur.execute("CREATE CATALOG mem USING memtable")
    mem = conn.warehouse.catalogs.get("mem").handler
    rng = np.random.default_rng(2)
    mem.load("clicks", VectorBatch({
        "item": rng.integers(0, 60, 6000),
        "n": rng.integers(1, 5, 6000),
    }))
    for sql in [
        "SELECT item, SUM(n) AS c FROM mem.default.clicks"
        " GROUP BY item ORDER BY c DESC, item",
        "SELECT cat, SUM(n) AS c FROM mem.default.clicks"
        " JOIN dim ON item = dk GROUP BY cat ORDER BY cat",
    ]:
        assert_parity(conn.warehouse, sql, extra=SHUFFLY)


def test_distinct_and_grouping_sets_partition_parity(conn):
    for sql in [
        "SELECT s, COUNT(DISTINCT grp) AS d FROM fact GROUP BY s ORDER BY s",
        "SELECT grp, COUNT(DISTINCT fk) AS d, SUM(v) AS sv FROM fact"
        " GROUP BY grp ORDER BY grp",
        "SELECT COUNT(DISTINCT fk) FROM fact",
        "SELECT DISTINCT s FROM fact ORDER BY s",
        "SELECT grp, s, SUM(v) AS sv FROM fact"
        " GROUP BY GROUPING SETS ((grp, s), (grp), ())"
        " ORDER BY grp, s, sv",
    ]:
        assert_parity(conn.warehouse, sql)


def test_global_distinct_uses_merging_fold(conn):
    """Global COUNT(DISTINCT x) partitions on x: per-lane partial counts
    fold through a merging Aggregate vertex."""
    s = conn.warehouse.session(**PART4)
    text = s.explain("SELECT COUNT(DISTINCT fk) FROM fact")
    assert "SHUFFLE partitions=4" in text
    # EXPLAIN ANALYZE captures the expanded plan: lane reads are visible
    r = db.connect(warehouse=conn.warehouse, **PART4).execute(
        "EXPLAIN ANALYZE SELECT COUNT(DISTINCT fk) FROM fact")
    analyzed = "\n".join(x[0] for x in r.fetchall())
    assert "ShuffleRead" in analyzed
    base = conn.execute("SELECT COUNT(DISTINCT fk) FROM fact").fetchone()
    four = db.connect(warehouse=conn.warehouse, **PART4)
    assert four.execute("SELECT COUNT(DISTINCT fk) FROM fact").fetchone() \
        == base
    four.close()


def test_sum_avg_distinct_deduplicate(conn):
    """SUM/AVG(DISTINCT x) really deduplicate (the pre-streaming fallback
    silently computed the plain SUM), at 1 and N partitions."""
    cur = conn.cursor()
    cur.execute("CREATE TABLE dd (g INT, x INT)")
    cur.execute("INSERT INTO dd VALUES (1, 10), (1, 10), (1, 20),"
                " (2, 5), (2, 5), (2, 5)")
    sql = ("SELECT g, SUM(DISTINCT x), AVG(DISTINCT x), COUNT(DISTINCT x)"
           " FROM dd GROUP BY g ORDER BY g")
    for parts in (1, 4):
        c = db.connect(warehouse=conn.warehouse, result_cache=False,
                       **{"shuffle.partitions": parts})
        assert c.execute(sql).fetchall() == [
            (1, 30, 15.0, 2), (2, 5, 5.0, 1)], parts
        assert c.execute("SELECT SUM(DISTINCT x) FROM dd").fetchone()[0] \
            == 35
        c.close()


def test_streaming_distinct_empty_and_null_inputs(conn):
    """The incremental distinct state handles empty inputs (0, not a crash)
    and skips NULL values like the materialized path did."""
    four = db.connect(warehouse=conn.warehouse, **PART4)
    assert four.execute(
        "SELECT COUNT(DISTINCT fk) FROM fact WHERE v > 9999").fetchone()[0] == 0
    assert four.execute(
        "SELECT s, COUNT(DISTINCT grp) FROM fact WHERE v > 9999"
        " GROUP BY s").fetchall() == []
    four.close()


# ---------------------------------------------------------------------------
# per-partition state, skew, spill
# ---------------------------------------------------------------------------
def test_poll_reports_per_lane_state(conn):
    """Build/probe and aggregation state is per-partition: every partitioned
    edge reports 4 lanes whose row counts sum to the edge total."""
    four = db.connect(warehouse=conn.warehouse, **PART4, **SHUFFLY)
    h = four.execute_async(
        "SELECT cat, SUM(v) AS sv FROM fact JOIN dim ON fk = dk"
        " GROUP BY cat ORDER BY cat")
    h.result(60)
    lanes = h.poll()["lanes"]
    # join build + probe edges and the aggregation input edge all partitioned
    assert len(lanes) >= 3
    for vid, per_lane in lanes.items():
        assert len(per_lane) == 4
        assert sum(l["rows"] for l in per_lane) > 0
    four.close()


def test_skewed_keys_spill_and_replay_identity(conn):
    """A heavily skewed key under a tiny per-lane budget spills on the hot
    lane and still returns results identical to the unconstrained run —
    and the skew is visible in the per-lane telemetry."""
    cur = conn.cursor()
    cur.execute("CREATE TABLE skew (k INT, v DOUBLE)")
    rng = np.random.default_rng(5)
    keys = np.where(rng.uniform(size=6000) < 0.9, 7,
                    rng.integers(0, 64, 6000))  # ~90% of rows share key 7
    rows = ", ".join(f"({int(k)}, {float(x):.4f})"
                     for k, x in zip(keys, rng.uniform(0, 1, 6000)))
    cur.execute(f"INSERT INTO skew VALUES {rows}")
    sql = "SELECT k, COUNT(*) AS n, SUM(v) AS sv FROM skew GROUP BY k ORDER BY k"
    free = db.connect(warehouse=conn.warehouse, **PART1)
    expect = free.execute(sql).fetchall()
    tight = db.connect(warehouse=conn.warehouse, **PART4,
                       **{"exchange.batch_rows": 64,
                          "exchange.buffer_rows": 512,
                          "exchange.buffer_bytes": 1 << 30})
    h = tight.execute_async(sql)
    got = h.result(60).fetchall()
    assert rounded(got) == rounded(expect)
    p = h.poll()
    lane_rows = [l["rows"] for lanes in p["lanes"].values() for l in lanes]
    assert max(lane_rows) > 10 * max(1, min(lane_rows))  # skew observable
    spilled = [l for lanes in p["lanes"].values() for l in lanes
               if l["spilled_rows"] > 0]
    assert spilled, "hot lane exceeded its budget slice but never spilled"
    for c in (free, tight):
        c.close()


def test_barrier_mode_partition_parity(conn):
    """exchange.pipeline=False (and reopt re-execution) filters lanes from
    materialized batches instead of lane exchanges — same results."""
    sql = ("SELECT cat, COUNT(*) AS n FROM fact JOIN dim ON fk = dk"
           " GROUP BY cat ORDER BY cat")
    assert_parity(conn.warehouse, sql,
                  extra={**SHUFFLY, "exchange.pipeline": False})


def test_explain_shows_partitioned_exchanges(conn):
    s = conn.warehouse.session(**PART4, **SHUFFLY)
    text = s.explain("SELECT cat, SUM(v) FROM fact JOIN dim ON fk = dk"
                     " GROUP BY cat")
    assert "exchanges:" in text
    assert "SHUFFLE partitions=4" in text
    assert "FORWARD" in text
    # single-lane sessions show plain edges, no partition annotations
    s1 = conn.warehouse.session(**PART1)
    t1 = s1.explain("SELECT grp, SUM(v) FROM fact GROUP BY grp")
    assert "partitions=" not in t1


def test_shuffle_partitions_in_plan_cache_key(conn):
    wh = conn.warehouse
    sql = "SELECT grp, SUM(v) FROM fact GROUP BY grp"
    one = db.connect(warehouse=wh, **PART1)
    four = db.connect(warehouse=wh, **PART4)
    one.execute(sql)
    r = four.execute(sql)
    # different shuffle.partitions never share a cached plan entry
    assert not r.info.get("plan_cache_hit")
    r2 = four.execute(sql)
    assert r2.info.get("plan_cache_hit") or r2.info.get("cache_hit")
    for c in (one, four):
        c.close()


def test_auto_partitions_small_input_stays_single_lane(conn):
    s = conn.warehouse.session(result_cache=False,
                               **{"shuffle.partitions": "auto"})
    text = s.explain("SELECT grp, SUM(v) FROM fact GROUP BY grp")
    assert "partitions=" not in text  # 4k rows < auto threshold


def test_auto_scan_fed_aggregate_demands_larger_payoff(conn):
    """BENCH_PR5 regression: ``auto`` declines fan-out for scan-fed
    aggregates below the lane-payoff threshold (the exchange hop costs more
    than the parallelism buys), while join-fed consumers at the same row
    estimate still expand."""
    from repro.core.optimizer import plan as P
    from repro.core.optimizer.rules import Optimizer
    from repro.core.runtime.shuffle import expand_shuffle_partitions
    from repro.core.sql.binder import Binder
    from repro.core.sql.parser import parse

    hms = conn.warehouse.hms

    class FakeEst:
        def __init__(self, rows):
            self.rows = rows

    class FakeCM:
        def __init__(self, rows):
            self._rows = rows

        def estimate(self, node):
            return FakeEst(self._rows)

    def plan_for(sql):
        return Optimizer(hms).optimize(Binder(hms).bind(parse(sql)))

    def lanes(sql, est_rows):
        out = expand_shuffle_partitions(
            plan_for(sql), {"shuffle.partitions": "auto"},
            cost_model=FakeCM(est_rows))
        return any(isinstance(n, P.ShuffleRead) for n in P.walk_plan(out))

    scan_fed = "SELECT grp, SUM(v) FROM fact GROUP BY grp"
    join_fed = ("SELECT cat, SUM(v) AS s FROM fact JOIN dim ON fk = dk"
                " GROUP BY cat")
    # 240k rows: several multiples of the generic per-lane share, but below
    # the scan-fed payoff threshold -> the plain aggregate stays single-lane
    assert not lanes(scan_fed, 240_000)
    assert lanes(join_fed, 240_000)
    # far past the payoff threshold the scan-fed aggregate fans out too
    assert lanes(scan_fed, 2_000_000)


def test_auto_partitions_derive_from_cbo_estimates():
    from repro.core.runtime.shuffle import (auto_partition_cap,
                                            resolve_partition_count)

    cap = auto_partition_cap()
    assert resolve_partition_count("auto", None) == 1
    assert resolve_partition_count("auto", 1000) == 1
    assert resolve_partition_count("auto", 100_000) == min(4, cap)
    assert resolve_partition_count("auto", 10**9) == cap
    assert resolve_partition_count(6, None) == 6
    assert resolve_partition_count(1, 10**9) == 1


# ---------------------------------------------------------------------------
# connector statistics -> CBO (ROADMAP satellite)
# ---------------------------------------------------------------------------
def test_connector_stats_feed_cost_model(conn):
    from repro.core.optimizer.cost import CostModel

    jd = conn.warehouse.handlers.get("jdbc")
    rng = np.random.default_rng(0)
    jd.load_table("orders", VectorBatch({
        "uid": rng.integers(0, 500, 20_000),
        "price": rng.uniform(0, 50, 20_000).round(4),
    }))
    cur = conn.cursor()
    cur.execute("CREATE EXTERNAL TABLE orders (uid INT, price DOUBLE)"
                " STORED BY 'jdbc' TBLPROPERTIES ('jdbc.table'='orders')")
    desc = conn.warehouse.hms.get_table("orders")
    stats = jd.scan_builder(desc).estimate_stats()
    assert stats.row_count == 20_000
    assert stats.columns["uid"].ndv == 500
    assert stats.columns["uid"].min_value == 0

    from repro.core.optimizer import plan as P

    cm = CostModel(conn.warehouse.hms,
                   handler_resolver=conn.warehouse.resolve_handler)
    est = cm.estimate(P.FederatedScan(desc, "o", ["uid", "price"]))
    assert est.rows == 20_000
    assert est.col("o.uid").ndv == 500
    # without the resolver the old empty-stats default applies
    cm0 = CostModel(conn.warehouse.hms)
    assert cm0.estimate(P.FederatedScan(desc, "o", ["uid", "price"])).rows <= 1


def test_memtable_catalog_stats(conn):
    cur = conn.cursor()
    cur.execute("CREATE CATALOG evc USING memtable")
    mem = conn.warehouse.catalogs.get("evc").handler
    rng = np.random.default_rng(1)
    mem.load("ev", VectorBatch({"k": rng.integers(0, 64, 5000),
                                "x": rng.uniform(0, 1, 5000)}))
    # resolve through the binder so the TableDesc carries the catalog handler
    r = conn.execute("SELECT COUNT(*) FROM evc.default.ev")
    assert r.fetchone()[0] == 5000
    desc = conn.warehouse.catalogs.get("evc").table_desc("default", "ev")
    st = mem.scan_builder(desc).estimate_stats()
    assert st.row_count == 5000 and st.columns["k"].ndv == 64


def test_federated_join_order_uses_remote_stats(conn):
    """With remote stats, the small external side broadcasts; the big side
    stays the probe side (previously both were empty-stats defaults)."""
    jd = conn.warehouse.handlers.get("jdbc")
    rng = np.random.default_rng(4)
    jd.load_table("big", VectorBatch({
        "k": rng.integers(0, 300, 50_000),
        "x": rng.uniform(0, 1, 50_000).round(4)}))
    jd.load_table("small", VectorBatch({
        "k": np.arange(300), "lbl": np.array([f"l{i % 9}" for i in range(300)])}))
    cur = conn.cursor()
    cur.execute("CREATE EXTERNAL TABLE big (k INT, x DOUBLE) STORED BY 'jdbc'"
                " TBLPROPERTIES ('jdbc.table'='big')")
    cur.execute("CREATE EXTERNAL TABLE small (k INT, lbl STRING)"
                " STORED BY 'jdbc' TBLPROPERTIES ('jdbc.table'='small')")
    r = conn.execute("SELECT lbl, SUM(x) AS sx FROM big JOIN small"
                     " ON big.k = small.k GROUP BY lbl ORDER BY lbl")
    assert r.info["dag_edges"]["BROADCAST"] >= 1
    assert len(r.fetchall()) == 9


# ---------------------------------------------------------------------------
# druid sorted-scan pushdown (ROADMAP satellite)
# ---------------------------------------------------------------------------
def test_druid_sorted_scan_limit_pushdown(conn):
    dr = conn.warehouse.handlers.get("druid")
    dr.store.segment_rows = 2500
    rng = np.random.default_rng(6)
    dr.store.create_datasource("events", VectorBatch({
        "ts": rng.permutation(9000),
        "val": rng.uniform(0, 1, 9000).round(5),
    }))
    cur = conn.cursor()
    cur.execute("CREATE EXTERNAL TABLE dev STORED BY 'druid'"
                " TBLPROPERTIES ('druid.datasource'='events')")
    dr.store.queries_served.clear()
    sql = "SELECT ts, val FROM dev ORDER BY ts DESC LIMIT 9"
    got = conn.execute(sql).fetchall()
    off = conn.warehouse.session(result_cache=False,
                                 **{"federation.push_limit": False})
    expect = off.execute(sql).rows
    assert [r[0] for r in got] == [r[0] for r in expect]
    assert [r[0] for r in got] == sorted(
        [r[0] for r in got], reverse=True)
    pushed = [q for q in dr.store.queries_served
              if q["queryType"] == "scan" and q.get("limitSpec")]
    assert pushed, "sorted scan query did not carry a limitSpec"
    assert pushed[0]["limitSpec"]["columns"][0]["dimension"] == "ts"
    # multi-segment: per-split top-n merges locally (PARTIAL, not FULL)
    desc = conn.warehouse.hms.get_table("dev")
    b = dr.scan_builder(desc)
    mode = b.push_limit(9, [(0, True)])
    assert mode == "partial"
    assert len(b.to_splits()) > 1
