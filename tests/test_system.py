"""End-to-end system behaviour: a BI-style workload exercising every paper
axis at once — ACID writes, optimizer, LLAP, result cache, MV, federation."""
import numpy as np
import pytest


def test_end_to_end_warehouse_scenario(tmp_path):
    from repro.core.session import Warehouse

    wh = Warehouse(str(tmp_path / "wh"))
    s = wh.session()

    # -- DDL with partitioning (paper §3.1 / Figure 3)
    s.execute("""CREATE TABLE store_sales (
        ss_item_sk INT, ss_customer_sk INT, ss_qty INT,
        ss_price DECIMAL(7,2), ss_sold_date_sk INT
    ) PARTITIONED BY (ss_sold_date_sk INT)""")
    s.execute("CREATE TABLE item (i_item_sk INT, i_category STRING)")

    rng = np.random.default_rng(11)
    rows = ", ".join(
        f"({rng.integers(0, 40)}, {rng.integers(0, 100)}, {rng.integers(1, 9)},"
        f" {rng.uniform(1, 50):.2f}, {d})"
        for d in range(10) for _ in range(200)
    )
    s.execute(f"INSERT INTO store_sales VALUES {rows}")
    items = ", ".join(
        f"({i}, '{['Sports', 'Books', 'Home', 'Toys'][i % 4]}')" for i in range(40)
    )
    s.execute(f"INSERT INTO item VALUES {items}")

    # partition directories exist on disk (physical layout, Figure 3)
    parts = wh.hms.list_partitions("store_sales")
    assert len(parts) == 10

    # -- interactive query with every optimization on
    sql = """SELECT i_category, SUM(ss_price * ss_qty) AS rev
             FROM store_sales, item
             WHERE ss_item_sk = i_item_sk AND ss_sold_date_sk BETWEEN 2 AND 5
             GROUP BY i_category ORDER BY rev DESC"""
    r1 = s.execute(sql)
    assert r1.num_rows == 4 and r1.info["cache_hit"] is False
    r2 = s.execute(sql)
    assert r2.info["cache_hit"] is True

    # -- ACID update flows through and invalidates the cache
    s.execute("UPDATE store_sales SET ss_qty = ss_qty + 1 WHERE ss_item_sk = 0")
    r3 = s.execute(sql)
    assert r3.info["cache_hit"] is False
    assert r3.rows != r1.rows  # totals changed

    # -- snapshot isolation survived the partitioned update
    total = s.execute("SELECT COUNT(*) FROM store_sales").rows[0][0]
    assert total == 2000

    # -- MV accelerates a rollup and survives incremental rebuild
    s.execute("""CREATE MATERIALIZED VIEW cat_daily AS
        SELECT ss_sold_date_sk, i_category, SUM(ss_price) AS s
        FROM store_sales, item WHERE ss_item_sk = i_item_sk
        GROUP BY ss_sold_date_sk, i_category""")
    q_mv = ("SELECT i_category, SUM(ss_price) s FROM store_sales, item"
            " WHERE ss_item_sk = i_item_sk GROUP BY i_category")
    r4 = s.execute(q_mv)
    assert r4.info.get("mv_used") == "cat_daily"
    ref = wh.session(mv_rewriting=False, result_cache=False).execute(q_mv)
    assert sorted((a, round(b, 6)) for a, b in r4.rows) == \
        sorted((a, round(b, 6)) for a, b in ref.rows)

    # -- EXPLAIN shows a DAG with data-movement edges
    text = s.explain(sql)
    assert "Scan[store_sales" in text and "DAG edges" in text

    # -- LLAP counters moved
    assert wh.llap.counters["cache_hits"] + wh.llap.counters["cache_misses"] > 0


def test_acid_at_par_after_compaction(tmp_path):
    """§8: post-compaction ACID read cost ~ non-ACID (single base, no merge)."""
    from repro.core.acid import AcidTable, list_stores
    from repro.core.compaction import compact_partition
    from repro.core.session import Warehouse

    wh = Warehouse(str(tmp_path / "wh"))
    s = wh.session(compaction_enabled=False)
    s.execute("CREATE TABLE t (k INT, v DOUBLE)")
    for i in range(8):
        vals = ", ".join(f"({j}, {j * 0.5})" for j in range(i * 50, (i + 1) * 50))
        s.execute(f"INSERT INTO t VALUES {vals}")
    s.execute("DELETE FROM t WHERE k < 20")
    tbl = AcidTable(wh.hms.get_table("t"), wh.hms)
    assert len(list_stores(tbl.desc.location)) >= 9  # many deltas pre-compaction
    before = s.execute("SELECT COUNT(*), SUM(v) FROM t").rows
    compact_partition(tbl, tbl.desc.location, "major", wh.hms)
    stores = list_stores(tbl.desc.location)
    assert [x.kind for x in stores] == ["base"]  # history folded away
    after = wh.session(result_cache=False).execute(
        "SELECT COUNT(*), SUM(v) FROM t").rows
    assert before == after
