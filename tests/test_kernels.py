"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.bloom.ops import probe_bloom_filter
from repro.kernels.filter_eval.ops import filter_eval
from repro.kernels.filter_eval.ref import filter_eval_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hash_group.ops import hash_group
from repro.kernels.hash_group.ref import hash_group_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@pytest.mark.parametrize("n,ncols", [(100, 1), (1024, 2), (5000, 3), (8192, 2)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int64])
def test_filter_eval_sweep(n, ncols, dtype):
    rng = np.random.default_rng(n)
    cols = [jnp.asarray(rng.uniform(0, 100, n).astype(dtype)) for _ in range(ncols)]
    ops = tuple((i % 6) for i in range(ncols))
    lits = tuple(float(rng.uniform(20, 80)) for _ in range(ncols))
    got = filter_eval(cols, ops, lits)
    exp = filter_eval_ref(cols, ops, lits)
    assert (np.array(got) == np.array(exp)).all()


@pytest.mark.parametrize("n,g", [(100, 5), (4096, 128), (10_000, 37), (2048, 1000)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_hash_group_sweep(n, g, dtype):
    rng = np.random.default_rng(g)
    codes = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(dtype))
    s1, c1 = hash_group(codes, vals, g)
    s2, c2 = hash_group_ref(codes, vals, g)
    np.testing.assert_allclose(np.array(s1), np.array(s2), atol=1e-3)
    np.testing.assert_array_equal(np.array(c1), np.array(c2))


@pytest.mark.parametrize("shape", [(1, 1, 64, 16), (2, 3, 128, 32),
                                   (2, 2, 256, 64), (1, 4, 96, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    B, H, S, d = shape
    rng = np.random.default_rng(S)
    q = jnp.asarray(rng.normal(size=shape), dtype)
    k = jnp.asarray(rng.normal(size=shape), dtype)
    v = jnp.asarray(rng.normal(size=shape), dtype)
    bq = 32 if S % 32 == 0 else S
    got = flash_attention(q, k, v, block_q=bq, block_k=bq)
    exp = attention_ref(q, k, v)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(exp, np.float32), atol=atol)


@pytest.mark.parametrize("cfg", [(1, 32, 2, 8, 4, 8), (2, 64, 3, 16, 8, 16),
                                 (1, 128, 4, 32, 16, 32), (2, 96, 2, 8, 8, 48)])
def test_ssd_scan_sweep(cfg):
    B, S, H, P, N, Q = cfg
    rng = np.random.default_rng(S)
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32)) * 0.1
    dA = -jnp.abs(jnp.asarray(rng.normal(size=(B, S, H)).astype(np.float32))) * 0.2
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32)) * 0.3
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32)) * 0.3
    got, _ = ssd_scan(x, dA, Bm, Cm, chunk=Q)
    exp = ssd_scan_ref(x, dA, Bm, Cm, chunk=Q)
    np.testing.assert_allclose(np.array(got), np.array(exp), atol=5e-5, rtol=1e-3)


def test_ssd_scan_chunk_invariance():
    """Same result regardless of chunking — the invariant behind SSD."""
    rng = np.random.default_rng(0)
    B, S, H, P, N = 1, 64, 2, 8, 4
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32)) * 0.1
    dA = -jnp.abs(jnp.asarray(rng.normal(size=(B, S, H)).astype(np.float32))) * 0.2
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32)) * 0.3
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32)) * 0.3
    y8, _ = ssd_scan(x, dA, Bm, Cm, chunk=8)
    y32, _ = ssd_scan(x, dA, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.array(y8), np.array(y32), atol=5e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), k=st.integers(1, 500))
def test_property_bloom_kernel_matches_host(n, k):
    from repro.core.bloomfilter import BloomFilter

    rng = np.random.default_rng(n * 1000 + k)
    members = rng.integers(0, 1_000_000, k)
    bf = BloomFilter.for_expected(k)
    bf.add(members)
    queries = np.concatenate([members, rng.integers(0, 1_000_000, n)])
    got = probe_bloom_filter(bf, queries)
    exp = bf.might_contain(queries)
    assert (got == exp).all()
