"""Materialized views: rewriting, staleness, incremental maintenance (§4.4)."""
import numpy as np
import pytest


MV_SQL = """CREATE MATERIALIZED VIEW mv1 AS
SELECT d_year, d_moy, SUM(ss_price) AS sum_sales
FROM store_sales, date_dim WHERE ss_date_sk = d_date_sk AND d_year > 2017
GROUP BY d_year, d_moy"""


@pytest.fixture()
def with_mv(star_schema):
    s = star_schema.session()
    s.execute(MV_SQL)
    return star_schema


def _pair(wh, sql):
    on = wh.session(result_cache=False).execute(sql)
    off = wh.session(mv_rewriting=False, result_cache=False).execute(sql)
    return on, off


def test_full_containment_rewrite(with_mv):
    sql = ("SELECT SUM(ss_price) AS s FROM store_sales, date_dim"
           " WHERE ss_date_sk = d_date_sk AND d_year = 2018 AND d_moy IN (1,2,3)")
    on, off = _pair(with_mv, sql)
    assert on.info.get("mv_used") == "mv1"
    assert on.info.get("mv_mode") == "full"
    assert abs(on.rows[0][0] - off.rows[0][0]) < 1e-6


def test_rollup_rewrite(with_mv):
    sql = ("SELECT d_year, SUM(ss_price) s FROM store_sales, date_dim"
           " WHERE ss_date_sk = d_date_sk AND d_year > 2017 GROUP BY d_year")
    on, off = _pair(with_mv, sql)
    assert on.info.get("mv_used") == "mv1"
    assert sorted((a, round(b, 6)) for a, b in on.rows) == \
        sorted((a, round(b, 6)) for a, b in off.rows)


def test_partial_containment_union_rewrite(with_mv):
    sql = ("SELECT d_year, SUM(ss_price) s FROM store_sales, date_dim"
           " WHERE ss_date_sk = d_date_sk AND d_year > 2016 GROUP BY d_year")
    on, off = _pair(with_mv, sql)
    assert on.info.get("mv_mode") == "partial"
    assert sorted((a, round(b, 6)) for a, b in on.rows) == \
        sorted((a, round(b, 6)) for a, b in off.rows)


def test_no_rewrite_when_not_contained(with_mv):
    # filter on a column the MV neither exposes nor constrains identically
    sql = ("SELECT SUM(ss_price) s FROM store_sales, date_dim"
           " WHERE ss_date_sk = d_date_sk AND d_year > 2017 AND ss_qty > 5")
    on, off = _pair(with_mv, sql)
    assert on.info.get("mv_used") is None
    assert abs(on.rows[0][0] - off.rows[0][0]) < 1e-6


def test_stale_mv_not_used_then_incremental_rebuild(with_mv):
    s = with_mv.session(result_cache=False)
    s.execute("INSERT INTO store_sales VALUES (5, 30, 7, 2, 42.5)")  # d_year 2018
    sql = ("SELECT SUM(ss_price) AS s FROM store_sales, date_dim"
           " WHERE ss_date_sk = d_date_sk AND d_year = 2018 AND d_moy IN (1,2,3)")
    r = s.execute(sql)
    assert r.info.get("mv_used") is None  # stale -> skipped
    rr = s.execute("ALTER MATERIALIZED VIEW mv1 REBUILD")
    assert rr.info["rebuild_mode"] == "incremental"
    on, off = _pair(with_mv, sql)
    assert on.info.get("mv_used") == "mv1"
    assert abs(on.rows[0][0] - off.rows[0][0]) < 1e-6


def test_delete_forces_full_rebuild(with_mv):
    s = with_mv.session(result_cache=False)
    s.execute("DELETE FROM store_sales WHERE ss_qty = 3")
    rr = s.execute("ALTER MATERIALIZED VIEW mv1 REBUILD")
    assert rr.info["rebuild_mode"] == "full"
    sql = ("SELECT d_year, SUM(ss_price) s FROM store_sales, date_dim"
           " WHERE ss_date_sk = d_date_sk AND d_year > 2017 GROUP BY d_year")
    on, off = _pair(with_mv, sql)
    assert on.info.get("mv_used") == "mv1"
    assert sorted((a, round(b, 6)) for a, b in on.rows) == \
        sorted((a, round(b, 6)) for a, b in off.rows)


def test_avg_rewrites_via_sum_count(with_mv):
    s = with_mv.session(result_cache=False)
    s.execute("""CREATE MATERIALIZED VIEW mv_avg AS
      SELECT d_year, SUM(ss_price) AS s, COUNT(ss_price) AS c
      FROM store_sales, date_dim WHERE ss_date_sk = d_date_sk
      GROUP BY d_year""")
    sql = ("SELECT d_year, AVG(ss_price) a FROM store_sales, date_dim"
           " WHERE ss_date_sk = d_date_sk GROUP BY d_year")
    on, off = _pair(with_mv, sql)
    assert on.info.get("mv_used") == "mv_avg"
    assert sorted((a, round(b, 9)) for a, b in on.rows) == \
        sorted((a, round(b, 9)) for a, b in off.rows)
