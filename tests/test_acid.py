"""ACID / transaction-manager behaviour (paper §3.2)."""
import numpy as np
import pytest
from _hypothesis_compat import HealthCheck, given, settings, st

from repro.core.acid import AcidTable, list_stores
from repro.core.compaction import CompactionConfig, compact_partition, maybe_compact
from repro.core.metastore import LockConflict, Metastore, WriteConflict
from repro.core.runtime.vector import VectorBatch


def _mk(hms, name="t", partitioned=False):
    cols = [("k", "INT"), ("v", "DOUBLE")]
    pcols = []
    if partitioned:
        cols.append(("p", "INT"))
        pcols = ["p"]
    hms.create_table(name, cols, partition_cols=pcols)
    return AcidTable(hms.get_table(name), hms)


def _insert(hms, tbl, ks, vs, ps=None):
    tx = hms.open_txn()
    cols = {"k": np.asarray(ks), "v": np.asarray(vs, dtype=float)}
    if ps is not None:
        cols["p"] = np.asarray(ps)
    tbl.insert(tx, VectorBatch(cols))
    hms.commit_txn(tx)
    return tx


def _read_ks(hms, tbl):
    wl = hms.writeid_list(tbl.desc.name, hms.get_snapshot())
    return sorted(tbl.read_all(wl).cols["k"].tolist())


def test_snapshot_isolation_uncommitted_invisible(tmp_path):
    hms = Metastore(str(tmp_path))
    tbl = _mk(hms)
    _insert(hms, tbl, [1, 2], [1.0, 2.0])
    tx = hms.open_txn()
    tbl.insert(tx, VectorBatch({"k": np.array([3]), "v": np.array([3.0])}))
    assert _read_ks(hms, tbl) == [1, 2]  # open txn invisible
    hms.commit_txn(tx)
    assert _read_ks(hms, tbl) == [1, 2, 3]


def test_aborted_rows_never_visible(tmp_path):
    hms = Metastore(str(tmp_path))
    tbl = _mk(hms)
    tx = hms.open_txn()
    tbl.insert(tx, VectorBatch({"k": np.array([9]), "v": np.array([9.0])}))
    hms.abort_txn(tx)
    assert _read_ks(hms, tbl) == []
    # even after compaction
    compact_partition(tbl, tbl.desc.location, "major", hms)
    assert _read_ks(hms, tbl) == []


def test_old_snapshot_sees_deleted_rows(tmp_path):
    hms = Metastore(str(tmp_path))
    tbl = _mk(hms)
    _insert(hms, tbl, [1, 2, 3], [1, 2, 3])
    old_wl = hms.writeid_list("t", hms.get_snapshot())
    tx = hms.open_txn()
    tbl.delete(tx, {(): np.array([[1, 0]], dtype=np.int64)})
    hms.commit_txn(tx)
    assert _read_ks(hms, tbl) == [2, 3]
    assert sorted(tbl.read_all(old_wl).cols["k"].tolist()) == [1, 2, 3]


def test_first_commit_wins_conflict(tmp_path):
    hms = Metastore(str(tmp_path))
    tbl = _mk(hms, partitioned=True)
    _insert(hms, tbl, [1, 2], [1, 2], ps=[0, 0])
    ta, tb = hms.open_txn(), hms.open_txn()
    tbl.delete(ta, {(0,): np.array([[1, 0]], dtype=np.int64)})
    tbl.delete(tb, {(0,): np.array([[1, 1]], dtype=np.int64)})
    hms.commit_txn(ta)
    with pytest.raises(WriteConflict):
        hms.commit_txn(tb)
    assert hms.txn_state(tb) == "aborted"


def test_disjoint_partitions_no_conflict(tmp_path):
    hms = Metastore(str(tmp_path))
    tbl = _mk(hms, partitioned=True)
    _insert(hms, tbl, [1, 2], [1, 2], ps=[0, 1])
    ta, tb = hms.open_txn(), hms.open_txn()
    tbl.delete(ta, {(0,): np.array([[1, 0]], dtype=np.int64)})
    tbl.delete(tb, {(1,): np.array([[1, 0]], dtype=np.int64)})
    hms.commit_txn(ta)
    hms.commit_txn(tb)  # no conflict


def test_exclusive_lock_blocks(tmp_path):
    hms = Metastore(str(tmp_path))
    _mk(hms)
    ta, tb = hms.open_txn(), hms.open_txn()
    hms.acquire_lock(ta, "t", None, "exclusive")
    with pytest.raises(LockConflict):
        hms.acquire_lock(tb, "t", None, "shared")
    hms.abort_txn(ta)  # releases locks
    hms.acquire_lock(tb, "t", None, "shared")


def test_compaction_equivalence_and_cleanup(tmp_path):
    hms = Metastore(str(tmp_path))
    tbl = _mk(hms)
    for i in range(6):
        _insert(hms, tbl, [i * 10 + j for j in range(5)], [0.0] * 5)
    tx = hms.open_txn()
    tbl.delete(tx, {(): np.array([[1, 0], [2, 1]], dtype=np.int64)})
    hms.commit_txn(tx)
    before = _read_ks(hms, tbl)
    # minor first, then major
    compact_partition(tbl, tbl.desc.location, "minor", hms)
    assert _read_ks(hms, tbl) == before
    compact_partition(tbl, tbl.desc.location, "major", hms)
    assert _read_ks(hms, tbl) == before
    stores = list_stores(tbl.desc.location)
    assert [s.kind for s in stores] == ["base"]


def test_auto_compaction_thresholds(tmp_path):
    hms = Metastore(str(tmp_path))
    tbl = _mk(hms)
    for i in range(12):
        _insert(hms, tbl, [i], [float(i)])
    actions = maybe_compact(tbl, hms, CompactionConfig(
        minor_delta_threshold=10, major_ratio_threshold=100.0))
    assert any(v == "minor" for v in actions.values())


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(ops=st.lists(
    st.tuples(st.sampled_from(["insert", "delete", "compact_minor",
                               "compact_major"]),
              st.integers(0, 99)),
    min_size=1, max_size=20))
def test_property_acid_matches_oracle(tmp_path_factory, ops):
    """Random interleavings of insert/delete/compaction match a dict oracle."""
    hms = Metastore(str(tmp_path_factory.mktemp("acid")))
    tbl = _mk(hms)
    oracle = {}  # k -> v (id-keyed rows)
    next_key = [0]
    for op, arg in ops:
        if op == "insert":
            ks = [next_key[0] + i for i in range(arg % 4 + 1)]
            next_key[0] += len(ks)
            _insert(hms, tbl, ks, [float(k) for k in ks])
            for k in ks:
                oracle[k] = float(k)
        elif op == "delete" and oracle:
            victim = sorted(oracle)[arg % len(oracle)]
            wl = hms.writeid_list("t", hms.get_snapshot())
            full = tbl.read_all(wl, keep_acid_cols=True)
            mask = full.cols["k"] == victim
            t = np.stack([full.cols["__writeid__"][mask],
                          full.cols["__rowid__"][mask]], axis=1)
            tx = hms.open_txn()
            tbl.delete(tx, {(): t})
            hms.commit_txn(tx)
            del oracle[victim]
        elif op == "compact_minor":
            compact_partition(tbl, tbl.desc.location, "minor", hms)
        else:
            compact_partition(tbl, tbl.desc.location, "major", hms)
        assert _read_ks(hms, tbl) == sorted(oracle)


# ---------------------------------------------------------------------------
# DDL invalidation (seed bug regression): DROP + CREATE under the same name
# ---------------------------------------------------------------------------
def test_drop_create_same_name_purges_old_rows(warehouse):
    """DROP TABLE must purge the managed table's data files and LLAP cache,
    so a re-created table with the same name never scans stale delta stores
    (the seed bug: 4 old + 4 new rows, COUNT(*) said 8)."""
    s = warehouse.session()
    s.execute("CREATE TABLE dr (a INT)")
    s.execute("INSERT INTO dr VALUES (1), (2), (3), (4)")
    assert s.execute("SELECT COUNT(*) FROM dr").rows == [(4,)]
    s.execute("SELECT a FROM dr")  # warm the LLAP chunk/meta caches
    s.execute("DROP TABLE dr")
    s.execute("CREATE TABLE dr (a INT)")
    s.execute("INSERT INTO dr VALUES (10), (20), (30), (40)")
    assert s.execute("SELECT COUNT(*) FROM dr").rows == [(4,)]
    assert s.execute("SELECT a FROM dr ORDER BY a").rows == \
        [(10,), (20,), (30,), (40,)]


def test_drop_table_removes_data_dir_and_llap_entries(warehouse):
    import os

    s = warehouse.session()
    s.execute("CREATE TABLE gone (a INT)")
    s.execute("INSERT INTO gone VALUES (1), (2)")
    loc = warehouse.hms.get_table("gone").location
    s.execute("SELECT a FROM gone")
    assert os.path.isdir(loc)
    cached = [p for p in warehouse.llap._meta if p.startswith(loc)]
    assert cached  # scan populated the footer cache
    s.execute("DROP TABLE gone")
    assert not os.path.isdir(loc)
    assert not [p for p in warehouse.llap._meta if p.startswith(loc)]
