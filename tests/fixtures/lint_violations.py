"""Seeded violations for the invariant lint — one per checker.

This file is NOT part of the warehouse; it exists so tests (and the CLI
exit-code contract) can prove every REP checker actually fires.  Keep one
deliberate violation per code, nothing else — test_analysis.py asserts the
exact finding set.
"""
import threading
import time


def read_knob(config):
    # REP001: key is not declared in repro.core.config_keys
    return config.get("definitely.not.a.declared.key", 7)


def stream_edge(exchange):
    # REP002: generator drains a reader without observing the cancel token
    for chunk in exchange.reader():
        yield chunk


def hoard(self, node):
    # REP003: full materialization outside the allowlist
    return self._collect(node)


_lock = threading.Lock()
_cond = threading.Condition(_lock)


def bare_acquire():
    # REP004a: bare acquire with no immediate try/finally release
    _lock.acquire()
    do_work = 1 + 1
    _lock.release()
    return do_work


def bare_wait():
    with _cond:
        # REP004b: wait outside a predicate loop
        _cond.wait()


def hijack_running_query(dag, vertex):
    # REP005: structural mutation of a live DAG outside the validating
    # adopt-helper (no check_dag, no rollback)
    dag.vertices.pop("v3", None)
    vertex.deps = ["v9"]


def conjure_columns(VectorBatch, np, inputs):
    # REP006: operator invents output columns as a dict literal instead of
    # deriving them from the input batch or the declared schema
    for batch in inputs:
        yield VectorBatch({"made_up": np.zeros(batch.num_rows)})


def stamp_split(split):
    # REP007: raw clock read in a traced subsystem — timing must go through
    # repro.core.obs.clock so traces/metrics share one clock
    return (split, time.monotonic())
