"""Batch-pipelined vectorized execution (PR 3).

Covers: chunked-operator parity against the materialized baseline on
randomized inputs, spill-and-replay correctness under a tiny exchange
budget, first-batch-before-root-completion streaming, cancellation at
operator batch boundaries (including under speculative execution), WLM
per-pool FIFO admission, and pallas/ref engine parity for the newly
dispatched kernels (bloom_probe, MIN/MAX hash_group, key_lookup).
"""
import time

import numpy as np
import pytest

import repro.api as db
from repro.core.runtime.cancel import QueryCancelledError
from repro.core.runtime.exec import Executor, MemoryPressureError
from repro.core.sql.parser import parse

TINY = {"exchange.batch_rows": 64, "result_cache": False}


def wait_for(cond, timeout=10.0, interval=0.01, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out after {timeout}s waiting for {what}")


def rounded(rows):
    def norm(x):
        if isinstance(x, float):
            return "NULL" if np.isnan(x) else round(x, 6)
        return x

    return sorted(tuple(norm(x) for x in r) for r in rows)


@pytest.fixture()
def conn(tmp_path):
    c = db.connect(str(tmp_path / "wh"))
    cur = c.cursor()
    cur.execute("CREATE TABLE fact (fk INT, grp INT, v DOUBLE, s STRING)")
    cur.execute("CREATE TABLE dim (dk INT, cat STRING, weight DOUBLE)")
    rng = np.random.default_rng(7)
    fk = rng.integers(0, 40, 3000)
    grp = rng.integers(0, 13, 3000)
    v = rng.uniform(-50, 50, 3000)
    rows = ", ".join(
        f"({int(a)}, {int(g)}, {float(x):.4f}, 's{int(a) % 5}')"
        for a, g, x in zip(fk, grp, v)
    )
    cur.execute(f"INSERT INTO fact VALUES {rows}")
    rows = ", ".join(f"({i}, 'c{i % 4}', {i * 0.25})" for i in range(35))
    cur.execute(f"INSERT INTO dim VALUES {rows}")
    yield c
    c.close()


PARITY_QUERIES = [
    "SELECT grp, COUNT(*) AS n, SUM(v) AS s, MIN(v) AS mn, MAX(v) AS mx,"
    " AVG(v) AS av FROM fact GROUP BY grp ORDER BY grp",
    "SELECT s, COUNT(DISTINCT grp) AS d FROM fact GROUP BY s ORDER BY s",
    "SELECT fk, v FROM fact WHERE v > 10 ORDER BY v DESC LIMIT 17",
    "SELECT cat, SUM(v) AS s, MIN(fk) AS mn FROM fact JOIN dim ON fk = dk"
    " WHERE weight > 2 GROUP BY cat ORDER BY cat",
    "SELECT d.cat, f.v FROM dim d LEFT JOIN fact f ON d.dk = f.fk"
    " WHERE d.dk >= 38",
    "SELECT fk FROM fact WHERE fk IN (SELECT dk FROM dim WHERE weight > 8)"
    " ORDER BY fk LIMIT 25",
    "SELECT grp AS g FROM fact WHERE v > 45 UNION ALL"
    " SELECT dk AS g FROM dim WHERE weight > 8",
    "SELECT grp AS g FROM fact UNION SELECT dk AS g FROM dim",
    "SELECT COUNT(*), SUM(v), MIN(v), MAX(v) FROM fact WHERE v > 1000",
    "SELECT grp, v, row_number() OVER (PARTITION BY grp ORDER BY v) AS rn"
    " FROM fact WHERE v > 40",
]


def test_chunked_operator_parity_vs_materialized(conn):
    """Tiny-morsel pipelined execution returns exactly what the
    materialize-every-vertex baseline returns, query by query."""
    wh = conn.warehouse
    piped = db.connect(warehouse=wh, **TINY)
    mat = db.connect(warehouse=wh, result_cache=False,
                     **{"exchange.pipeline": False})
    for sql in PARITY_QUERIES:
        a = piped.execute(sql).fetchall()
        b = mat.execute(sql).fetchall()
        assert rounded(a) == rounded(b), sql
    for c in (piped, mat):
        c.close()


def test_spill_and_replay_matches_unconstrained(conn):
    """A constrained exchange budget completes via spill with results
    identical to the unconstrained run, and poll() reports the spill."""
    wh = conn.warehouse
    sql = ("SELECT cat, v FROM fact JOIN dim ON fk = dk"
           " ORDER BY v DESC LIMIT 50")
    free = db.connect(warehouse=wh, **TINY)
    tight = db.connect(warehouse=wh, **TINY,
                       **{"exchange.buffer_rows": 128,
                          "exchange.buffer_bytes": 1 << 14})
    expect = free.execute(sql).fetchall()
    h = tight.execute_async(sql)
    got = h.result(60).fetchall()
    assert rounded(got) == rounded(expect)
    p = h.poll()
    assert p["rows_spilled"] > 0
    assert p["bytes_spilled"] > 0
    assert any(v["rows"] > 0 for v in p["spill"].values())
    for c in (free, tight):
        c.close()


def test_partitioned_scan_all_filtered_keeps_schema(conn):
    """A chunked scan whose every stripe filters out still yields a
    schema-carrying empty batch including partition columns."""
    cur = conn.cursor()
    cur.execute("CREATE TABLE pt (x INT, y DOUBLE) PARTITIONED BY (p INT)")
    cur.execute("INSERT INTO pt VALUES (1, 1.0, 10), (2, 2.0, 20)")
    c = db.connect(warehouse=conn.warehouse, **TINY)
    assert c.execute("SELECT p, x FROM pt WHERE x > 999").fetchall() == []
    assert c.execute("SELECT p, SUM(x) FROM pt WHERE x > 999"
                     " GROUP BY p").fetchall() == []
    c.close()


def test_spill_off_overflow_recovers_via_reopt(conn):
    """With reopt enabled, a spill-disabled exchange overflow re-executes on
    materialized exchanges and still returns correct results."""
    s = conn.warehouse.session(
        result_cache=False,
        **{"exchange.batch_rows": 64, "exchange.buffer_rows": 128,
           "exchange.spill": False})
    r = s.execute("SELECT cat, COUNT(*) FROM fact JOIN dim ON fk = dk"
                  " GROUP BY cat ORDER BY cat")
    assert r.info.get("reexecuted") is True
    baseline = conn.execute("SELECT cat, COUNT(*) FROM fact JOIN dim"
                            " ON fk = dk GROUP BY cat ORDER BY cat").fetchall()
    assert rounded(r.rows) == rounded(baseline)


def test_spill_disabled_raises_memory_pressure(conn):
    s = conn.warehouse.session(
        result_cache=False, reopt_mode="off",
        **{"exchange.batch_rows": 64, "exchange.buffer_rows": 128,
           "exchange.spill": False})
    with pytest.raises(MemoryPressureError):
        s.execute("SELECT cat, v FROM fact JOIN dim ON fk = dk ORDER BY v")


def test_fetch_stream_first_batch_before_root_finishes(conn):
    """SSB-style scan-filter-project: the first streamed batch arrives while
    the root (and only) vertex is still producing morsels."""
    wh = conn.warehouse
    c = db.connect(warehouse=wh, **TINY)
    h = c.execute_async("SELECT fk, v * 2 FROM fact WHERE v > -100")
    polls, batches = [], []
    for batch in h.fetch_stream(batch_rows=64):
        if not batches:
            polls.append(h.poll())
        batches.append(batch)
    # backpressure (queue of 2 pages, 64 rows each) guarantees the producer
    # was still mid-vertex when the consumer pulled the first page
    assert polls[0]["vertices_done"] < max(polls[0]["vertices_total"], 1)
    assert polls[0]["state"] == "RUNNING"
    assert len(batches) > 10
    assert sum(len(b) for b in batches) == 3000
    c.close()


def test_cancel_observed_at_batch_boundaries(conn):
    """A tripped token stops an operator loop at the next morsel instead of
    draining the stream (ROADMAP: speculated-clone cancel latency)."""
    from repro.core.runtime.cancel import CancelToken

    wh = conn.warehouse
    s = wh.session(result_cache=False, **{"exchange.batch_rows": 64})
    plan, _ = s._plan_query(parse("SELECT fk, v FROM fact WHERE v > -100"))
    token = CancelToken()
    ctx = s._make_ctx(dict(s.config), cancel_token=token)
    gen = Executor(ctx).stream(plan)
    first = next(gen)
    assert first.num_rows > 0
    token.cancel("test cancel mid-stream")
    with pytest.raises(QueryCancelledError):
        next(gen)


def test_cancel_mid_vertex_under_speculation(conn):
    """Speculative mode runs the barrier scheduler, but operator loops still
    poll the token every morsel: cancelling mid-vertex (the speculated-clone
    regression) terminates promptly."""
    wh = conn.warehouse
    calls = []

    from repro.core.runtime.exec import _SCALAR_FUNCS

    def slow_ident(args):
        calls.append(1)
        time.sleep(0.02)
        return args[0]

    _SCALAR_FUNCS["slow_ident_pr3"] = slow_ident
    try:
        c = db.connect(warehouse=wh, speculative_execution=True,
                       result_cache=False, **{"exchange.batch_rows": 32})
        h = c.execute_async("SELECT slow_ident_pr3(v) FROM fact")
        wait_for(lambda: len(calls) >= 3, what="vertex mid-stream")
        t0 = time.monotonic()
        h.cancel()
        wait_for(h.done, what="cancelled handle terminal")
        assert time.monotonic() - t0 < 2.0  # ~one morsel, not 94 of them
        assert h.state == "CANCELLED"
        seen = len(calls)
        time.sleep(0.1)
        assert len(calls) <= seen + 2  # the loop stopped at a batch boundary
        c.close()
    finally:
        _SCALAR_FUNCS.pop("slow_ident_pr3", None)


def test_cancel_latency_bounded_under_partitioned_lanes(conn):
    """With shuffle.partitions > 1 every per-partition clone observes the
    token at its own batch boundaries: cancelling mid-shuffle terminates
    within ~one morsel, not after draining every lane."""
    from repro.core.runtime.exec import _SCALAR_FUNCS

    calls = []

    def slow_ident(args):
        calls.append(1)
        time.sleep(0.02)
        return args[0]

    _SCALAR_FUNCS["slow_ident_pr5"] = slow_ident
    try:
        c = db.connect(warehouse=conn.warehouse, result_cache=False,
                       **{"exchange.batch_rows": 32,
                          "shuffle.partitions": 4,
                          "broadcast_threshold_rows": 0.0})
        h = c.execute_async(
            "SELECT grp, SUM(slow_ident_pr5(v)) FROM fact"
            " JOIN dim ON fk = dk GROUP BY grp")
        wait_for(lambda: len(calls) >= 3, what="clone mid-stream")
        t0 = time.monotonic()
        h.cancel()
        wait_for(h.done, what="cancelled handle terminal")
        assert time.monotonic() - t0 < 2.0
        assert h.state == "CANCELLED"
        seen = len(calls)
        time.sleep(0.1)
        # each of the (at most 4) running clones stops at a batch boundary
        assert len(calls) <= seen + 8
        c.close()
    finally:
        _SCALAR_FUNCS.pop("slow_ident_pr5", None)


# ---------------------------------------------------------------------------
# WLM fair admission
# ---------------------------------------------------------------------------
def test_wlm_fifo_admission_and_queue_depth(conn):
    cur = conn.cursor()
    for ddl in [
        "CREATE RESOURCE PLAN solo",
        "CREATE POOL solo.only WITH alloc_fraction=1.0, query_parallelism=1",
        "ALTER PLAN solo SET DEFAULT POOL = only",
        "ALTER RESOURCE PLAN solo ENABLE ACTIVATE",
    ]:
        cur.execute(ddl)
    slow = db.connect(warehouse=conn.warehouse, result_cache=False,
                      debug_vertex_delay_s=0.25)
    handles = [slow.execute_async("SELECT COUNT(*) FROM fact WHERE fk > ?",
                                  (0,))]
    wait_for(lambda: handles[0].state == "RUNNING", what="first running")
    for i in range(1, 4):
        depth_before = conn.warehouse.wlm.queue_depths().get("only", 0)
        h = slow.execute_async("SELECT COUNT(*) FROM fact WHERE fk > ?", (i,))
        # wait until this handle is measurably parked in its pool's queue,
        # so arrival order into the per-pool FIFO is deterministic
        wait_for(lambda: conn.warehouse.wlm.queue_depths().get("only", 0)
                 > depth_before, what=f"handle {i} queued")
        handles.append(h)
    depths = [p.poll().get("pool_queue_depth", {}).get("only", 0)
              for p in handles]
    assert max(depths) >= 1  # queue depth surfaced through poll()
    for h in handles:
        h.result(60)
    admitted = [h._task.admitted_at for h in handles]
    assert admitted == sorted(admitted)  # per-pool FIFO, not FIFO-by-wakeup
    slow.close()


# ---------------------------------------------------------------------------
# widened kernel dispatch: pallas/ref parity
# ---------------------------------------------------------------------------
def test_bloom_probe_engine_parity():
    from repro.core.bloomfilter import BloomFilter
    from repro.kernels.bloom.ops import probe_bloom_filter

    rng = np.random.default_rng(3)
    keys = rng.integers(0, 100_000, 4000)
    bf = BloomFilter.for_expected(len(keys))
    bf.add(keys)
    queries = rng.integers(0, 200_000, 8192)
    host = bf.might_contain(queries)
    pallas = np.asarray(probe_bloom_filter(bf, queries, engine="pallas"))
    ref = np.asarray(probe_bloom_filter(bf, queries, engine="ref"))
    assert np.array_equal(pallas, ref)
    assert np.array_equal(pallas, host)


def test_minmax_kernel_engine_parity():
    from repro.kernels.hash_group.ops import hash_group_minmax

    rng = np.random.default_rng(5)
    codes = rng.integers(0, 200, 10_000).astype(np.int32)
    vals = rng.integers(-1000, 1000, 10_000).astype(np.float32)
    out = {}
    for eng in ("pallas", "ref"):
        mins, maxs = hash_group_minmax(codes, vals, 200, engine=eng)
        out[eng] = (np.asarray(mins), np.asarray(maxs))
    assert np.array_equal(out["pallas"][0], out["ref"][0])
    assert np.array_equal(out["pallas"][1], out["ref"][1])
    for g in (0, 17, 199):
        sel = vals[codes == g]
        assert out["ref"][0][g] == sel.min()
        assert out["ref"][1][g] == sel.max()


def test_key_lookup_engine_parity():
    from repro.kernels.key_lookup.ops import key_lookup

    rng = np.random.default_rng(11)
    uniq = np.unique(rng.integers(0, 3000, 900)).astype(np.float32)
    probe = rng.integers(-50, 3500, 5000).astype(np.float32)
    got = {eng: np.asarray(key_lookup(uniq, probe, engine=eng))
           for eng in ("pallas", "ref")}
    assert np.array_equal(got["pallas"], got["ref"])
    hit = got["ref"] >= 0
    assert np.array_equal(uniq[got["ref"][hit]], probe[hit])
    assert not np.isin(probe[~hit], uniq).any()


def test_engine_parity_full_query_path(conn):
    """bloom_probe (semijoin reducers), MIN/MAX + SUM/COUNT (hash_group*),
    key_lookup (join probes), filter_eval: one SSB-shaped query, all
    engines, identical rows."""
    wh = conn.warehouse
    sql = ("SELECT cat, COUNT(*) AS n, SUM(fk) AS s, MIN(fk) AS mn,"
           " MAX(fk) AS mx FROM fact JOIN dim ON fk = dk"
           " WHERE weight > 6 AND fk >= 0 GROUP BY cat ORDER BY cat")
    results = {}
    for eng in ("auto", "pallas", "ref"):
        c = db.connect(warehouse=wh, engine=eng, **TINY)
        results[eng] = c.execute(sql).fetchall()
        c.close()
    assert results["auto"] == results["pallas"] == results["ref"]
    assert len(results["auto"]) > 0


def test_exchange_single_consumer_frees_chunks(tmp_path):
    """FORWARD-edge refcounting: with retention off, each chunk (memory and
    spill file) is released as its one reader consumes it."""
    import os

    from repro.core.runtime.exchange import Exchange, ExchangeConfig
    from repro.core.runtime.vector import VectorBatch

    cfg = ExchangeConfig({"exchange.buffer_rows": 64},
                         scratch_dir=str(tmp_path / "scratch"))
    ex = Exchange("v1", cfg)
    ex.retain = False
    for i in range(6):
        ex.put(VectorBatch({"x": np.arange(48) + i * 48}))
    ex.close()
    assert ex.spilled_chunks > 0  # budget forced some chunks to disk
    spilled = [s.path for s in ex._slots
               if type(s).__name__ == "_DiskSlot"]
    rows = sum(b.num_rows for b in ex.reader())
    assert rows == 6 * 48
    st = ex.stats()
    assert st["freed_chunks"] == 6
    assert all(slot is None for slot in ex._slots)
    assert all(not os.path.exists(p) for p in spilled)  # unlinked on read
    # a second pass over a single-consumer edge is a hard error, not junk
    with pytest.raises(RuntimeError, match="already freed"):
        next(iter(ex.reader()))
    ex.discard()
    cfg.cleanup()


def test_multi_consumer_exchange_still_replays(tmp_path):
    from repro.core.runtime.exchange import Exchange, ExchangeConfig
    from repro.core.runtime.vector import VectorBatch

    cfg = ExchangeConfig({"exchange.buffer_rows": 64},
                         scratch_dir=str(tmp_path / "scratch2"))
    ex = Exchange("v2", cfg)  # retain defaults to True
    for i in range(4):
        ex.put(VectorBatch({"x": np.arange(40) + i * 40}))
    ex.close()
    first = sum(b.num_rows for b in ex.reader())
    second = sum(b.num_rows for b in ex.reader())
    assert first == second == 160
    assert ex.stats()["freed_chunks"] == 0
    ex.discard()
    cfg.cleanup()


def test_shared_scan_refcounted_retention(tmp_path):
    """PR 6 shared scans: a published exchange outlives its producer while
    consumers are attached; the last release discards spill files and runs
    the deferred cleanup callback exactly once."""
    import os

    from repro.core.runtime.exchange import Exchange, ExchangeConfig
    from repro.core.runtime.vector import VectorBatch
    from repro.core.serving import SharedScanRegistry

    cfg = ExchangeConfig({"exchange.buffer_rows": 64},
                         scratch_dir=str(tmp_path / "scratch3"))
    ex = Exchange("scan", cfg)  # retain defaults to True
    reg = SharedScanRegistry()
    assert reg.publish(("k",), "t", ex)
    assert not reg.publish(("k",), "t", Exchange("dup", cfg))  # key taken
    for i in range(5):
        ex.put(VectorBatch({"x": np.arange(50) + i * 50}))
    ex.close()
    assert ex.spilled_chunks > 0
    spilled = [s.path for s in ex._slots if type(s).__name__ == "_DiskSlot"]

    h1 = reg.attach(("k",))
    h2 = reg.attach(("k",))
    assert h1 is not None and h2 is not None

    cleaned = []
    # producer tears down first: consumers still attached, so the registry
    # keeps the exchange and defers the producer's cleanup
    assert reg.retire(("k",), ex, on_final=lambda: cleaned.append(1)) is False
    assert reg.attach(("k",)) is None  # retired: no NEW attachments
    assert sum(b.num_rows for b in h1.reader()) == 250
    h1.release()
    h1.release()  # idempotent
    assert cleaned == []  # one consumer still attached
    assert all(os.path.exists(p) for p in spilled)
    assert sum(b.num_rows for b in h2.reader()) == 250  # full replay
    h2.release()
    assert cleaned == [1]  # deferred cleanup ran exactly once
    assert all(not os.path.exists(p) for p in spilled)  # discarded
    assert reg.stats_snapshot()["live_entries"] == 0
    cfg.cleanup()


def test_forward_edges_freed_during_pipelined_query(conn):
    """End-to-end: a pipelined scan->project query runs with single-consumer
    edges freeing as they go, and results stay correct."""
    rows = conn.execute(
        "SELECT fk, v FROM fact WHERE v > 5").fetchall()
    assert len(rows) > 0
    assert all(v > 5 for _, v in rows)
