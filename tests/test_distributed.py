"""Distributed runtime: shard_map relational ops, checkpoint/reshard,
gradient compression, DAG straggler mitigation.

Multi-device tests run in subprocesses because
--xla_force_host_platform_device_count must be set before jax initializes
(and the rest of the suite must see one device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_shard_map_relational_ops_8dev():
    out = run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        from repro.distributed.relational import (
            make_distributed_group_sum, make_shuffle_join, make_broadcast_join)
        rng = np.random.default_rng(0)
        codes = jnp.array(rng.integers(0, 64, 4096), jnp.int32)
        vals = jnp.array(rng.uniform(0, 1, 4096), jnp.float32)
        s, c = make_distributed_group_sum(mesh, 64)(codes, vals)
        exp = np.zeros(64); np.add.at(exp, np.array(codes), np.array(vals))
        assert np.allclose(np.array(s), exp, atol=1e-3)
        lk = jnp.array(rng.integers(0, 100, 1024), jnp.int32)
        lv = jnp.array(rng.uniform(0, 1, 1024), jnp.float32)
        rk = jnp.array(rng.permutation(200)[:128], jnp.int32)
        rv = jnp.array(rng.uniform(0, 1, 128), jnp.float32)
        ok, ol, orr, ovf = make_shuffle_join(mesh, 4096)(lk, lv, rk, rv)
        rset = set(np.array(rk).tolist())
        expected = sum(1 for k in np.array(lk) if int(k) in rset)
        got = int((np.array(ok) >= 0).sum())
        assert got == expected and int(ovf) == 0, (got, expected)
        bk, bl, br = make_broadcast_join(mesh)(lk, lv, rk, rv)
        assert int((np.array(bk) >= 0).sum()) == expected
        print("DIST_OK")
    """)
    assert "DIST_OK" in out


def test_elastic_checkpoint_reshard_4_to_8():
    out = run_sub("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.checkpoint import CheckpointManager
        mesh4 = jax.sharding.Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))
        mesh8 = jax.sharding.Mesh(np.array(jax.devices()).reshape(8), ("data",))
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        sh4 = {"w": NamedSharding(mesh4, P("data", None))}
        tree4 = {"w": jax.device_put(tree["w"], sh4["w"])}
        cm = CheckpointManager(tempfile.mkdtemp())
        cm.save(5, tree4, shardings=sh4)
        sh8 = {"w": NamedSharding(mesh8, P("data", None))}
        restored, step = cm.restore(tree, shardings=sh8)
        assert step == 5
        assert restored["w"].sharding == sh8["w"]
        assert bool(jnp.all(restored["w"] == tree["w"]))
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out


def test_compressed_psum_accuracy_8dev():
    out = run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.compression import psum_with_optional_compression
        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.array(np.random.default_rng(0).normal(size=(8, 4096)), jnp.float32)
        def f_c(x):
            return psum_with_optional_compression({"g": x}, "pod", True)["g"]
        def f_p(x):
            return psum_with_optional_compression({"g": x}, "pod", False)["g"]
        yc = jax.jit(shard_map(f_c, mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))(x)
        yp = jax.jit(shard_map(f_p, mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))(x)
        rel = float(jnp.max(jnp.abs(yc - yp)) / (jnp.max(jnp.abs(yp)) + 1e-9))
        assert rel < 0.02, rel  # int8 wire format, <2% worst-case error
        print("COMPRESS_OK", rel)
    """)
    assert "COMPRESS_OK" in out


def test_checkpoint_keep_policy(tmp_path):
    import jax.numpy as jnp

    from repro.distributed.checkpoint import CheckpointManager

    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.ones(4)}
    for s in [1, 2, 3, 4]:
        cm.save(s, tree)
    assert cm.list_steps() == [3, 4]


def test_preemption_handler_saves(tmp_path):
    import signal

    from repro.distributed.checkpoint import install_preemption_handler

    saved = []
    old = signal.getsignal(signal.SIGTERM)
    try:
        install_preemption_handler(lambda: saved.append(True))
        with pytest.raises(SystemExit):
            signal.raise_signal(signal.SIGTERM)
        assert saved == [True]
    finally:
        signal.signal(signal.SIGTERM, old)


def test_dag_speculative_execution(star_schema):
    """Straggler mitigation: an injected slow vertex is speculatively re-run."""
    from repro.core.runtime.dag import DAGScheduler, compile_dag
    from repro.core.sql.binder import Binder
    from repro.core.sql.parser import parse

    plan = Binder(star_schema.hms).bind(parse(
        "SELECT i_category, COUNT(*) FROM store_sales, item"
        " WHERE ss_item_sk = i_item_sk GROUP BY i_category"))
    from repro.core.optimizer.rules import Optimizer

    plan = Optimizer(star_schema.hms).optimize(plan)
    dag = compile_dag(plan)
    slow_vid = dag.topo_order()[0]
    sched = DAGScheduler(speculative=True, straggler_factor=2.0,
                         injected_delays={slow_vid: 3.0})
    ctx = star_schema.session()._make_ctx(
        {**star_schema.session().config, "result_cache": False})
    out = sched.execute(dag, ctx)
    assert out.num_rows == 5
    assert any(m.speculated for m in sched.metrics)
