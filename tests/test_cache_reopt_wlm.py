"""Query results cache (§4.3), re-optimization (§4.2), workload mgmt (§5.2)."""
import threading

import numpy as np
import pytest

from repro.core.runtime.wlm import QueryKilledError


SQL = ("SELECT i_category, SUM(ss_price) s FROM store_sales, item"
       " WHERE ss_item_sk = i_item_sk GROUP BY i_category ORDER BY s DESC")


def test_cache_hit_and_snapshot_invalidation(star_schema):
    s = star_schema.session()
    r1 = s.execute(SQL)
    assert r1.info["cache_hit"] is False
    r2 = s.execute(SQL)
    assert r2.info["cache_hit"] is True
    assert r2.rows == r1.rows
    # any write to a participating table invalidates (WriteId snapshot moves)
    s.execute("INSERT INTO store_sales VALUES (1, 1, 1, 1, 5.0)")
    r3 = s.execute(SQL)
    assert r3.info["cache_hit"] is False


def test_unrelated_write_keeps_cache(star_schema):
    s = star_schema.session()
    s.execute(SQL)
    s.execute("CREATE TABLE unrelated (x INT)")
    s.execute("INSERT INTO unrelated VALUES (1)")
    r = s.execute(SQL)
    assert r.info["cache_hit"] is True


def test_pending_entry_thundering_herd(star_schema):
    """Concurrent identical queries: one fills, the rest wait (§4.3)."""
    results, hits = [], []
    barrier = threading.Barrier(4)

    def run():
        s = star_schema.session()
        barrier.wait()
        r = s.execute(SQL)
        results.append(tuple(map(tuple, r.rows)))
        hits.append(r.info.get("cache_hit"))

    threads = [threading.Thread(target=run) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(set(results)) == 1
    assert star_schema.result_cache.stats["pending_waits"] >= 1 or \
        sum(1 for h in hits if h) >= 1


def test_reoptimize_on_memory_pressure(star_schema):
    s = star_schema.session(mapjoin_max_rows=10, reopt_mode="reoptimize",
                            result_cache=False, semijoin_reduction=False)
    r = s.execute(SQL)
    assert r.info.get("reexecuted") is True
    ref = star_schema.session(result_cache=False, mapjoin_max_rows=10**9).execute(SQL)
    assert [(a, round(b, 6)) for a, b in r.rows] == \
        [(a, round(b, 6)) for a, b in ref.rows]


def test_overlay_reexecution(star_schema):
    s = star_schema.session(mapjoin_max_rows=10, reopt_mode="overlay",
                            result_cache=False, semijoin_reduction=False)
    r = s.execute(SQL)
    assert r.info.get("reexecuted") is True
    assert r.info.get("reopt_mode") == "overlay"


def test_reopt_off_raises(star_schema):
    from repro.core.runtime.exec import MemoryPressureError

    s = star_schema.session(mapjoin_max_rows=10, reopt_mode="off",
                            result_cache=False, semijoin_reduction=False)
    with pytest.raises(MemoryPressureError):
        s.execute(SQL)


def test_runtime_stats_persisted(star_schema):
    s = star_schema.session(result_cache=False)
    s.execute(SQL)
    rows = star_schema.hms._q("SELECT COUNT(*) FROM runtime_stats")
    assert rows[0][0] > 0  # feedback loop for the §9 roadmap item


WLM_DDL = [
    "CREATE RESOURCE PLAN daytime",
    "CREATE POOL daytime.bi WITH alloc_fraction=0.8, query_parallelism=5",
    "CREATE POOL daytime.etl WITH alloc_fraction=0.2, query_parallelism=20",
    "CREATE RULE downgrade IN daytime WHEN total_runtime > 3000 THEN MOVE etl",
    "ADD RULE downgrade TO bi",
    "CREATE APPLICATION MAPPING visualization_app IN daytime TO bi",
    "ALTER PLAN daytime SET DEFAULT POOL = etl",
    "ALTER RESOURCE PLAN daytime ENABLE ACTIVATE",
]


def test_wlm_paper_example(star_schema):
    s = star_schema.session()
    for ddl in WLM_DDL:
        s.execute(ddl)
    plan = star_schema.wlm.active_plan
    assert plan.name == "daytime"
    assert plan.pools["bi"].alloc_fraction == 0.8
    assert plan.pools["bi"].query_parallelism == 5
    assert plan.rules["downgrade"].pools == ["bi"]
    r = star_schema.session(application="visualization_app",
                            result_cache=False).execute(
        "SELECT COUNT(*) FROM item")
    assert r.info["wlm_pool"] == "bi"
    r = star_schema.session(result_cache=False).execute(
        "SELECT COUNT(*) FROM item")
    assert r.info["wlm_pool"] == "etl"


def test_wlm_trigger_moves_query(star_schema):
    s = star_schema.session()
    for ddl in WLM_DDL:
        s.execute(ddl)
    wlm = star_schema.wlm
    slot = wlm.admit("qq", application="visualization_app")
    assert slot.pool == "bi"
    slot.admitted_at -= 10  # simulate 10s elapsed
    wlm.update_metrics("qq", rows_produced=1)
    assert slot.pool == "etl" and slot.moves == ["bi->etl"]
    wlm.release("qq")


def test_wlm_kill_trigger(star_schema):
    s = star_schema.session()
    for ddl in WLM_DDL:
        s.execute(ddl)
    wlm = star_schema.wlm
    wlm.create_rule("daytime", "reaper", "rows_produced", 100, "kill", None)
    wlm.activate("daytime")
    slot = wlm.admit("qk")
    with pytest.raises(QueryKilledError):
        wlm.update_metrics("qk", rows_produced=1000)
    wlm.release("qk")


def test_wlm_idle_capacity_borrowing(star_schema):
    s = star_schema.session()
    for ddl in WLM_DDL:
        s.execute(ddl)
    wlm = star_schema.wlm
    slots = [wlm.admit(f"q{i}", application="visualization_app")
             for i in range(5)]
    extra = wlm.admit("q-extra", application="visualization_app")
    assert extra.borrowed_from == "etl"  # bi full; borrows idle etl capacity
    for i in range(5):
        wlm.release(f"q{i}")
    wlm.release("q-extra")


def test_wlm_cross_pool_borrow_round_robin(warehouse):
    """Queue heads from several pools contending for borrowed idle capacity
    are granted round-robin across pools, not in wakeup order."""
    import time

    s = warehouse.session()
    for ddl in [
        "CREATE RESOURCE PLAN rr",
        "CREATE POOL rr.a WITH alloc_fraction=0.3, query_parallelism=1",
        "CREATE POOL rr.b WITH alloc_fraction=0.3, query_parallelism=1",
        "CREATE POOL rr.spare WITH alloc_fraction=0.4, query_parallelism=1",
        "CREATE USER MAPPING ua IN rr TO a",
        "CREATE USER MAPPING ub IN rr TO b",
        "ALTER PLAN rr SET DEFAULT POOL = spare",
        "ALTER RESOURCE PLAN rr ENABLE ACTIVATE",
    ]:
        s.execute(ddl)
    wlm = warehouse.wlm
    # saturate every pool so all further admissions must queue
    wlm.admit("a0", user="ua")
    wlm.admit("b0", user="ub")
    wlm.admit("sp0")

    grants = []  # (qid, granted pool) in admission order
    done = threading.Semaphore(0)

    def waiter(qid, user):
        slot = wlm.wait_admit(qid, user=user, timeout=30)
        grants.append((qid, slot.pool))
        done.release()

    threads = [threading.Thread(target=waiter, args=(f"{p}{i}", f"u{p}"))
               for i in (1, 2) for p in ("a", "b")]
    for t in threads:
        t.start()
    deadline = time.monotonic() + 10
    while not all(wlm.queue_depths().get(p, 0) == 2 for p in ("a", "b")):
        assert time.monotonic() < deadline, "admission queues never formed"
        time.sleep(0.01)

    # free the spare slot; each released borrower frees it again for the
    # next contending head -- grants must alternate a, b, a, b
    wlm.release("sp0")
    for k in range(4):
        assert done.acquire(timeout=10), f"grant {k} never arrived"
        qid, _pool = grants[k]
        wlm.release(qid)  # frees the borrowed spare capacity for the next
    for t in threads:
        t.join(timeout=10)
    assert [pool for _, pool in grants] == ["a", "b", "a", "b"]
    wlm.release("a0")
    wlm.release("b0")
