"""Public client API (repro.api): connection/cursor/prepared statements,
parameter binding, plan cache, staged pipeline timings, engine registry."""
import numpy as np
import pytest

import repro.api as db


@pytest.fixture()
def conn(tmp_path):
    c = db.connect(str(tmp_path / "wh"))
    cur = c.cursor()
    cur.execute("CREATE TABLE events (k INT, v DOUBLE, tag STRING)")
    rows = ", ".join(
        f"({i}, {i * 1.5}, '{['red', 'green', 'blue'][i % 3]}')"
        for i in range(257)
    )
    cur.execute(f"INSERT INTO events VALUES {rows}")
    yield c
    c.close()


# ---------------------------------------------------------------------------
# connection basics
# ---------------------------------------------------------------------------
def test_module_globals():
    assert db.apilevel == "2.0"
    assert db.paramstyle == "qmark"
    assert issubclass(db.ProgrammingError, db.DatabaseError)
    assert issubclass(db.DatabaseError, db.Error)


def test_connect_validation(tmp_path):
    with pytest.raises(db.InterfaceError):
        db.connect()  # neither dir nor warehouse
    with pytest.raises(db.ProgrammingError):
        db.connect(str(tmp_path / "wh"), no_such_option=1)
    with pytest.raises(db.ProgrammingError):
        db.connect(str(tmp_path / "wh"), engine="cuda")


def test_context_managers(tmp_path):
    with db.connect(str(tmp_path / "wh")) as conn:
        with conn.cursor() as cur:
            cur.execute("CREATE TABLE t (x INT)")
    assert conn.closed
    with pytest.raises(db.InterfaceError):
        conn.cursor()


def test_rollback_not_supported(conn):
    conn.commit()  # autocommit: a no-op, but allowed
    with pytest.raises(db.NotSupportedError):
        conn.rollback()


# ---------------------------------------------------------------------------
# cursor paging (fetchone / fetchmany / fetchall across page boundaries)
# ---------------------------------------------------------------------------
def test_fetchmany_pages_across_boundaries(conn):
    cur = conn.cursor()
    cur.execute("SELECT k FROM events ORDER BY k")
    assert cur.rowcount == 257
    got = []
    # uneven page sizes exercise boundary arithmetic incl. the short tail
    for size in (1, 100, 64, 64, 64):
        page = cur.fetchmany(size)
        assert len(page) <= size
        got.extend(r[0] for r in page)
    assert cur.fetchmany(10) == []  # exhausted
    assert got == list(range(257))


def test_fetchone_and_iteration(conn):
    cur = conn.cursor()
    cur.execute("SELECT k FROM events WHERE k < 5 ORDER BY k")
    assert cur.fetchone() == (0,)
    assert list(cur) == [(1,), (2,), (3,), (4,)]
    assert cur.fetchone() is None


def test_fetch_without_execute_raises(conn):
    cur = conn.cursor()
    with pytest.raises(db.InterfaceError):
        cur.fetchall()


def test_description_types(conn):
    cur = conn.cursor()
    cur.execute("SELECT k, v, tag FROM events LIMIT 1")
    names = [d[0] for d in cur.description]
    types = [d[1] for d in cur.description]
    assert names == ["k", "v", "tag"]
    assert types == ["BIGINT", "DOUBLE", "STRING"]


# ---------------------------------------------------------------------------
# parameter binding
# ---------------------------------------------------------------------------
def test_int_and_float_params(conn):
    cur = conn.cursor()
    cur.execute("SELECT COUNT(*) FROM events WHERE k >= ? AND v < ?",
                (250, 380.0))
    # k in [250, 253): v = 1.5k < 380 -> k < 253.33
    assert cur.fetchone() == (4,)


def test_string_param(conn):
    cur = conn.cursor()
    cur.execute("SELECT COUNT(*) FROM events WHERE tag = ?", ("green",))
    expected = len([i for i in range(257) if i % 3 == 1])
    assert cur.fetchone() == (expected,)


def test_null_param_roundtrip(conn):
    cur = conn.cursor()
    cur.execute("CREATE TABLE np (k INT, v DOUBLE)")
    cur.executemany("INSERT INTO np VALUES (?, ?)",
                    [(1, None), (2, 7.0)])
    cur.execute("SELECT k FROM np WHERE v IS NULL")
    assert cur.fetchall() == [(1,)]
    cur.execute("SELECT k FROM np WHERE v IS NOT NULL")
    assert cur.fetchall() == [(2,)]


def test_param_count_mismatch(conn):
    cur = conn.cursor()
    with pytest.raises(db.ProgrammingError):
        cur.execute("SELECT * FROM events WHERE k > ?")
    with pytest.raises(db.ProgrammingError):
        cur.execute("SELECT * FROM events WHERE k > ?", (1, 2))


def test_params_in_dml_update_delete(conn):
    cur = conn.cursor()
    cur.execute("CREATE TABLE dml (k INT, v DOUBLE)")
    cur.executemany("INSERT INTO dml VALUES (?, ?)",
                    [(i, float(i)) for i in range(10)])
    cur.execute("UPDATE dml SET v = ? WHERE k < ?", (99.0, 3))
    assert cur.rowcount == 3
    cur.execute("DELETE FROM dml WHERE v = ?", (99.0,))
    assert cur.rowcount == 3
    cur.execute("SELECT COUNT(*) FROM dml")
    assert cur.fetchone() == (7,)


# ---------------------------------------------------------------------------
# prepared statements / plan cache
# ---------------------------------------------------------------------------
def test_prepared_statement_plan_cache_hit(conn):
    ps = conn.prepare("SELECT k, v FROM events WHERE k > ? ORDER BY k")
    assert ps.is_query and ps.param_count == 1
    before = dict(conn.warehouse.plan_cache.stats)
    c1 = ps.execute((254,))
    assert c1.info.get("plan_cache_hit") is True  # warmed by prepare()
    assert c1.fetchall() == [(255, 382.5), (256, 384.0)]
    c2 = ps.execute((255,))  # different params reuse the same plan
    assert c2.info.get("plan_cache_hit") is True
    assert c2.fetchall() == [(256, 384.0)]
    after = conn.warehouse.plan_cache.stats
    assert after["hits"] >= before["hits"] + 2


def test_plain_execute_hits_plan_cache_second_time(conn):
    cur = conn.cursor()
    sql = "SELECT SUM(v) FROM events WHERE k < ?"
    r1 = cur.execute(sql, (100,)).info
    assert "plan_cache_hit" not in r1
    r2 = cur.execute(sql, (50,)).info  # different params -> same plan
    assert r2.get("plan_cache_hit") is True


def test_result_cache_key_includes_params(conn):
    cur = conn.cursor()
    sql = "SELECT COUNT(*) FROM events WHERE k < ?"
    a = cur.execute(sql, (10,)).fetchone()
    b = cur.execute(sql, (20,)).fetchone()
    assert a == (10,) and b == (20,)
    info = cur.execute(sql, (10,)).info  # same params -> result cache hit
    assert info.get("cache_hit") is True
    assert cur.fetchone() == (10,)


def test_prepare_rejects_bad_sql(conn):
    with pytest.raises(db.ProgrammingError):
        conn.prepare("SELECT * FROM missing_table")
    with pytest.raises(db.ProgrammingError):
        conn.prepare("SELEKT 1")
    ps = conn.prepare("SELECT k FROM events WHERE k = ?")
    with pytest.raises(db.ProgrammingError):
        ps.execute()  # missing parameter


def test_plan_cache_dropped_after_base_table_write(conn):
    """A cached MV-rewritten plan must not replay after base-table DML —
    the plan cache validates per-table WriteId state like the result cache."""
    cur = conn.cursor()
    cur.execute("CREATE TABLE base (g INT, x DOUBLE)")
    cur.execute("INSERT INTO base VALUES (1, 10.0), (2, 20.0)")
    cur.execute("CREATE MATERIALIZED VIEW mv_sum AS "
                "SELECT g, SUM(x) AS s FROM base GROUP BY g")
    sql = "SELECT g, SUM(x) FROM base GROUP BY g ORDER BY g"
    r1 = cur.execute(sql).fetchall()
    assert cur.info.get("mv_used") == "mv_sum"
    assert r1 == [(1, 10.0), (2, 20.0)]
    r2 = cur.execute(sql).fetchall()  # plan-cache hit, info preserved
    assert cur.info.get("plan_cache_hit") is True
    assert cur.info.get("mv_used") == "mv_sum"
    assert r2 == r1
    cur.execute("INSERT INTO base VALUES (1, 100.0)")
    r3 = cur.execute(sql).fetchall()  # stale MV plan must NOT replay
    assert cur.info.get("plan_cache_hit") is None
    assert r3 == [(1, 110.0), (2, 20.0)]


def test_kernel_filter_falls_back_beyond_float32(tmp_path):
    """Forced engines only use the float32 filter kernel when the cast is
    value-preserving; 2^24 + 1 must not collapse onto 2^24."""
    with db.connect(str(tmp_path / "wh"), engine="ref",
                    result_cache=False, pushdown=False) as c:
        cur = c.cursor()
        cur.execute("CREATE TABLE big (a INT)")
        cur.execute(f"INSERT INTO big VALUES ({1 << 24}), ({(1 << 24) + 1})")
        cur.execute(f"SELECT COUNT(*) FROM big WHERE a = {(1 << 24) + 1}")
        assert cur.fetchone() == (1,)


def test_plan_cache_invalidated_by_ddl(conn):
    cur = conn.cursor()
    cur.execute("SELECT COUNT(*) FROM events")
    cur.execute("SELECT COUNT(*) FROM events")
    assert len(conn.warehouse.plan_cache) > 0
    cur.execute("CREATE TABLE other (x INT)")
    assert len(conn.warehouse.plan_cache) == 0


# ---------------------------------------------------------------------------
# staged pipeline
# ---------------------------------------------------------------------------
def test_stage_times_in_info(conn):
    cur = conn.cursor()
    cur.execute("SELECT tag, COUNT(*) FROM events GROUP BY tag")
    st = cur.info.get("stage_times_ms")
    assert st is not None
    for stage in ("parse", "bind", "cache_probe", "mv_rewrite",
                  "optimize", "compile", "execute"):
        assert stage in st, f"missing stage {stage}"
        assert st[stage] >= 0


def test_explain_analyze_reports_stage_timings(conn):
    cur = conn.cursor()
    cur.execute("EXPLAIN ANALYZE SELECT tag, SUM(v) FROM events "
                "WHERE k > 10 GROUP BY tag")
    text = "\n".join(r[0] for r in cur.fetchall())
    assert "stage timings:" in text
    assert "execute:" in text and "optimize:" in text
    assert "Aggregate" in text  # the plan itself is included
    assert "stage_times_ms" in cur.info


def test_result_cache_not_shared_across_mv_rewriting_configs(conn):
    """An MV-rewriting session may serve stale-within-window MV data; a
    session with rewriting disabled must never get those rows from cache."""
    cur = conn.cursor()
    cur.execute("CREATE TABLE src (g INT, x DOUBLE)")
    cur.execute("INSERT INTO src VALUES (1, 1.0), (2, 2.0)")
    sql = "SELECT g, SUM(x) FROM src GROUP BY g ORDER BY g"
    cur.execute(sql)
    cur.execute(sql)
    assert cur.info["cache_hit"] is True
    with db.connect(warehouse=conn.warehouse, mv_rewriting=False) as c2:
        info = c2.execute(sql).info  # different cache identity -> fresh run
        assert info["cache_hit"] is False


def test_explain_analyze_bypasses_result_cache(conn):
    """EXPLAIN ANALYZE must execute and show the plan even when the plain
    query's result is already cached."""
    cur = conn.cursor()
    sql = "SELECT tag, COUNT(*) FROM events GROUP BY tag"
    cur.execute(sql)
    cur.execute(sql)
    assert cur.info["cache_hit"] is True
    cur.execute("EXPLAIN ANALYZE " + sql)
    text = "\n".join(r[0] for r in cur.fetchall())
    assert "Aggregate" in text and "execute:" in text
    assert cur.info["cache_hit"] is False


def test_explain_validates_param_count(conn):
    cur = conn.cursor()
    with pytest.raises(db.ProgrammingError):
        cur.execute("EXPLAIN SELECT k FROM events WHERE k > ?", (1, 2, 3))


def test_cache_hit_short_circuits_stages(conn):
    cur = conn.cursor()
    sql = "SELECT COUNT(*) FROM events WHERE tag = 'red'"
    miss = cur.execute(sql).info
    info = cur.execute(sql).info
    assert info["cache_hit"] is True
    st = info["stage_times_ms"]
    # a served hit reports the same stage keys as an executed query
    # (consumers key on stage names); the skipped post-probe stages are
    # zeroed, not absent — 0 ms spent, not "never happened"
    assert set(st) == set(miss["stage_times_ms"])
    assert st["execute"] == 0.0 and st["compile"] == 0.0
    assert st["cache_probe"] > 0.0


def test_legacy_session_execute_shim(conn):
    """Warehouse.session().execute() keeps working over the new pipeline."""
    s = conn.warehouse.session()
    r = s.execute("SELECT COUNT(*) FROM events")
    assert r.rows == [(257,)]
    assert r.info["cache_hit"] in (False, True)
    assert "stage_times_ms" in r.info
    r2 = s.execute("SELECT COUNT(*) FROM events WHERE k < ?", params=(5,))
    assert r2.rows == [(5,)]


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------
def test_engine_validation_in_session(conn):
    with pytest.raises(ValueError):
        conn.warehouse.session(engine="tpu-v9")


def test_engine_ref_matches_default(tmp_path):
    c_auto = db.connect(str(tmp_path / "wh"), result_cache=False)
    cur = c_auto.cursor()
    cur.execute("CREATE TABLE m (k INT, v DOUBLE)")
    cur.executemany("INSERT INTO m VALUES (?, ?)",
                    [(i, float(i % 7)) for i in range(64)])
    expect = cur.execute(
        "SELECT k FROM m WHERE v > 3 ORDER BY k").fetchall()
    for engine in ("ref", "pallas"):
        # attached connections share the live warehouse; closing them must
        # not tear it down (only the owning connection does that)
        with db.connect(warehouse=c_auto.warehouse, result_cache=False,
                        engine=engine) as c_eng:
            got = c_eng.execute(
                "SELECT k FROM m WHERE v > 3 ORDER BY k").fetchall()
            assert got == expect, engine
    c_auto.close()


def test_registry_resolution():
    from repro.kernels.registry import backends, resolve

    assert set(backends("filter_eval")) == {"pallas", "ref"}
    assert resolve("filter_eval", "ref") is not resolve("filter_eval",
                                                        "pallas")
    assert resolve("filter_eval", "auto") is resolve("filter_eval", "pallas")
    with pytest.raises(KeyError):
        resolve("no_such_kernel")
    with pytest.raises(ValueError):
        resolve("filter_eval", "cuda")


# ---------------------------------------------------------------------------
# plan-cache drift policy
# ---------------------------------------------------------------------------
def test_plan_cache_survives_small_writes(conn):
    """Non-MV plans stay cached across writes (scans re-resolve data at run
    time); only a >2x row-count shift re-optimizes."""
    cur = conn.cursor()
    q = "SELECT tag, COUNT(*) AS n FROM events GROUP BY tag ORDER BY tag"
    cur.execute(q)
    cur.execute(q)
    assert cur.info["plan_cache_hit"] is True
    cur.execute("INSERT INTO events VALUES (999, 1.0, 'red')")  # +1 row
    cur.execute(q)
    assert cur.info.get("plan_cache_hit") is True  # plan survived the write
    counts = dict(cur.fetchall())
    assert counts["red"] == 87  # ...and the new row is visible (86 + 1)


def test_plan_cache_drops_on_row_count_drift(conn):
    cur = conn.cursor()
    q = "SELECT tag, COUNT(*) AS n FROM events GROUP BY tag"
    cur.execute(q)
    cur.execute(q)
    assert cur.info["plan_cache_hit"] is True
    rows = ", ".join(f"({i}, 0.5, 'grey')" for i in range(600))  # 257 -> >2x
    cur.execute(f"INSERT INTO events VALUES {rows}")
    cur.execute(q)
    assert cur.info.get("plan_cache_hit") is None  # drift re-optimized


# ---------------------------------------------------------------------------
# grouped aggregation through the kernel registry
# ---------------------------------------------------------------------------
def test_engine_routes_grouped_aggregation_through_registry(tmp_path):
    """engine != auto dispatches SUM/COUNT through kernels.registry
    ('hash_group'), like filter conjunctions already do."""
    import repro.kernels.registry as registry

    c = db.connect(str(tmp_path / "wh"), result_cache=False)
    cur = c.cursor()
    cur.execute("CREATE TABLE g (k INT, v DOUBLE, n INT)")
    cur.execute("INSERT INTO g VALUES " + ", ".join(
        f"({i % 7}, {i * 0.5}, {i % 13})" for i in range(200)))
    q = ("SELECT k, SUM(v) AS sv, COUNT(v) AS cv, AVG(n) AS an "
         "FROM g GROUP BY k ORDER BY k")
    expect = cur.execute(q).fetchall()

    calls = []
    orig = registry.resolve

    def spy(kernel, engine="auto"):
        calls.append((kernel, engine))
        return orig(kernel, engine)

    registry.resolve = spy
    try:
        for engine in ("ref", "pallas"):
            calls.clear()
            with db.connect(warehouse=c.warehouse, result_cache=False,
                            engine=engine) as ce:
                got = ce.execute(q).fetchall()
            assert [k for k, _ in calls].count("hash_group") > 0, engine
            assert all(e == engine for k, e in calls if k == "hash_group")
            for exp_row, got_row in zip(expect, got):
                assert exp_row == pytest.approx(got_row), engine
    finally:
        registry.resolve = orig
    c.close()


def test_kernel_agg_falls_back_beyond_float32(tmp_path):
    """Integer SUMs that float32 accumulation cannot represent exactly must
    take the numpy path even under a forced engine."""
    with db.connect(str(tmp_path / "wh"), engine="ref",
                    result_cache=False) as c:
        cur = c.cursor()
        cur.execute("CREATE TABLE big (k INT, a INT)")
        cur.execute(f"INSERT INTO big VALUES (1, {1 << 24}), (1, 1)")
        cur.execute("SELECT k, SUM(a) AS s FROM big GROUP BY k")
        assert cur.fetchall() == [(1, (1 << 24) + 1)]
