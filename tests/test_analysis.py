"""Tests for the correctness toolkit: invariant lint (REP001..REP007),
lockdep sanitizer, structural plan validator, and the config-key registry
they hang off."""
import os
import subprocess
import sys
import threading
import time
import warnings

import numpy as np
import pytest

import repro.api as db
from repro.analysis import lint
from repro.analysis import lockdep
from repro.analysis.plan_validator import (PlanValidationError, check_dag,
                                           validate_dag)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO_ROOT, "src")
FIXTURE = os.path.join(REPO_ROOT, "tests", "fixtures", "lint_violations.py")


# ===========================================================================
# invariant lint
# ===========================================================================
class TestLint:
    def test_fixture_seeds_every_checker(self):
        findings = lint.lint_file(FIXTURE)
        codes = sorted(f.code for f in findings)
        assert codes == ["REP001", "REP002", "REP003", "REP004", "REP004",
                         "REP005", "REP005", "REP006", "REP007"]

    def test_rep001_declared_key_passes(self):
        src = 'def f(config):\n    return config.get("cbo", True)\n'
        assert lint.lint_source(src, "core/x.py") == []

    def test_rep001_undeclared_key_fires(self):
        src = 'def f(config):\n    return config.get("cbo_typo", True)\n'
        fs = lint.lint_source(src, "core/x.py")
        assert [f.code for f in fs] == ["REP001"]
        assert "cbo_typo" in fs[0].message

    def test_rep001_scope_excludes_model_code(self):
        src = 'def f(config):\n    return config.get("lr", 0.1)\n'
        assert lint.lint_source(src, "src/repro/models/x.py") == []

    def test_rep002_checked_loop_passes(self):
        src = ("def g(self, ex):\n"
               "    for chunk in ex.reader():\n"
               "        self._checkpoint()\n"
               "        yield chunk\n")
        assert lint.lint_source(src, "core/x.py") == []

    def test_rep002_non_generator_loop_exempt(self):
        src = ("def drain(ex):\n"
               "    out = []\n"
               "    for chunk in ex.reader():\n"
               "        out.append(chunk)\n"
               "    return out\n")
        assert lint.lint_source(src, "core/x.py") == []

    def test_rep003_allowlisted_site_passes(self):
        src = ("def _stream_sort(self, node):\n"
               "    return self._collect(node)\n")
        assert lint.lint_source(src, "src/repro/core/runtime/exec.py") == []
        fs = lint.lint_source(src, "src/repro/core/runtime/dag.py")
        assert [f.code for f in fs] == ["REP003"]

    def test_rep004_with_statement_passes(self):
        src = ("def f(lock):\n"
               "    with lock:\n"
               "        pass\n")
        assert lint.lint_source(src, "core/x.py") == []

    def test_rep004_acquire_try_finally_passes(self):
        src = ("def f(lock):\n"
               "    lock.acquire()\n"
               "    try:\n"
               "        pass\n"
               "    finally:\n"
               "        lock.release()\n")
        assert lint.lint_source(src, "core/x.py") == []

    def test_rep004_wait_for_and_event_wait_exempt(self):
        src = ("def f(cond, done):\n"
               "    with cond:\n"
               "        cond.wait_for(lambda: True)\n"
               "    done.wait(60)\n")  # Event.wait: receiver not a cond
        assert lint.lint_source(src, "core/x.py") == []

    def test_rep005_mutation_outside_adopt_fires(self):
        src = ("def steal(dag):\n"
               "    dag.vertices.pop('v1', None)\n"
               "    dag.vertices['v9'] = object()\n")
        fs = lint.lint_source(src, "src/repro/core/runtime/scheduler.py")
        assert [f.code for f in fs] == ["REP005", "REP005"]

    def test_rep006_dict_literal_in_operator_fires(self):
        src = ("def _stream_x(self, node):\n"
               "    for b in self.stream(node.input):\n"
               "        yield VectorBatch({'v': b.cols['v'] * 2})\n")
        fs = lint.lint_source(src, "src/repro/core/runtime/exec.py")
        assert [f.code for f in fs] == ["REP006"]
        assert "'v'" in fs[0].message

    def test_rep006_derived_and_dunder_pass(self):
        src = ("def _stream_x(self, node):\n"
               "    for b in self.stream(node.input):\n"
               "        yield VectorBatch({k: v for k, v in b.cols.items()})\n"
               "        yield VectorBatch(dict(zip(node.names, b.cols.values())))\n"
               "        yield VectorBatch({'__dummy__': b.cols['v']})\n")
        assert lint.lint_source(src, "src/repro/core/runtime/exec.py") == []

    def test_rep006_non_generator_passes(self):
        # result assembly outside operators (EXPLAIN output, CLI tables)
        # may hard-code columns: the rule is scoped to streaming operators
        src = ("def explain(self, sql):\n"
               "    return VectorBatch({'plan': lines})\n")
        assert lint.lint_source(src, "src/repro/core/session.py") == []

    def test_rep005_reads_pass(self):
        src = ("def peek(dag):\n"
               "    v = dag.vertices['v1']\n"
               "    return list(v.deps), dict(v.edge_types)\n")
        assert lint.lint_source(src, "src/repro/core/runtime/x.py") == []

    def test_rep005_apply_undo_closures_allowed_in_adaptive(self):
        src = ("def _collapse(self, dag):\n"
               "    def apply():\n"
               "        dag.vertices.pop('v1', None)\n"
               "    def undo():\n"
               "        dag.vertices['v1'] = object()\n"
               "    self._adopt(apply, undo, {})\n")
        path = "src/repro/core/runtime/adaptive.py"
        assert lint.lint_source(src, path) == []
        # the same mutations outside apply/undo still fire in adaptive.py
        bad = ("def _collapse(self, dag):\n"
               "    dag.vertices.pop('v1', None)\n")
        assert [f.code for f in lint.lint_source(bad, path)] == ["REP005"]

    def test_rep005_dag_py_construction_allowed(self):
        src = ("def compile_dag(plan):\n"
               "    dag.vertices['v1'] = object()\n"
               "    vertex.deps = ['v2']\n")
        assert lint.lint_source(src, "src/repro/core/runtime/dag.py") == []

    def test_suppression_comment(self):
        src = ('def f(config):\n'
               '    return config.get("oops")  # repro-lint: REP001\n')
        assert lint.lint_source(src, "core/x.py") == []

    def test_repo_is_clean(self):
        findings = lint.lint_paths([os.path.join(SRC, "repro")])
        assert findings == [], "\n".join(str(f) for f in findings)

    def test_cli_exit_codes(self):
        env = dict(os.environ, PYTHONPATH=SRC)
        clean = subprocess.run(
            [sys.executable, "-m", "repro.analysis",
             os.path.join(SRC, "repro")],
            capture_output=True, text=True, env=env, cwd=REPO_ROOT)
        assert clean.returncode == 0, clean.stdout + clean.stderr
        dirty = subprocess.run(
            [sys.executable, "-m", "repro.analysis", FIXTURE],
            capture_output=True, text=True, env=env, cwd=REPO_ROOT)
        assert dirty.returncode == 1, dirty.stdout + dirty.stderr
        for code in ("REP001", "REP002", "REP003", "REP004", "REP005",
                     "REP006", "REP007"):
            assert code in dirty.stdout


# ===========================================================================
# lockdep sanitizer
# ===========================================================================
@pytest.fixture()
def lockdep_on(monkeypatch):
    monkeypatch.setenv("REPRO_LOCKDEP", "1")
    lockdep.reset()
    yield
    lockdep.reset()


class TestLockdep:
    def test_factory_off_returns_plain_primitives(self, monkeypatch):
        monkeypatch.delenv("REPRO_LOCKDEP", raising=False)
        assert type(lockdep.make_lock("x")) is type(threading.Lock())
        assert isinstance(lockdep.make_condition(name="x"),
                          threading.Condition)
        assert not isinstance(lockdep.make_condition(name="x"),
                              lockdep.TrackedCondition)

    def test_ab_ba_inversion_detected_deterministically(self, lockdep_on):
        """One AB acquisition then one BA acquisition — in sequence, no
        interleaving race — must raise LockOrderError every run."""
        a, b = lockdep.make_lock("lk.A"), lockdep.make_lock("lk.B")
        with a:
            with b:
                pass
        caught = []

        def inverted():
            try:
                with b:
                    with a:
                        pass
            except lockdep.LockOrderError as exc:
                caught.append(exc)

        t = threading.Thread(target=inverted)
        t.start()
        t.join(10)
        assert len(caught) == 1
        assert "lk.A" in str(caught[0]) and "lk.B" in str(caught[0])

    def test_three_lock_cycle_detected(self, lockdep_on):
        a, b, c = (lockdep.make_lock(n) for n in ("c3.A", "c3.B", "c3.C"))
        with a, b:
            pass
        with b, c:
            pass
        with pytest.raises(lockdep.LockOrderError):
            with c, a:
                pass

    def test_consistent_order_never_raises(self, lockdep_on):
        a, b = lockdep.make_lock("ok.A"), lockdep.make_lock("ok.B")
        for _ in range(50):
            with a, b:
                pass
        assert lockdep.graph_snapshot()["ok.A"] == {"ok.B"}

    def test_reentrant_rlock_no_self_edge(self, lockdep_on):
        r = lockdep.make_rlock("re.R")
        with r, r:
            pass
        assert "re.R" not in lockdep.graph_snapshot()

    def test_same_name_siblings_not_a_cycle(self, lockdep_on):
        # lane arrays create many same-class locks; holding one while
        # touching another (in either order) must not trip the detector
        e1, e2 = lockdep.make_lock("exchange"), lockdep.make_lock("exchange")
        with e1:
            with e2:
                pass
        with e2:
            with e1:
                pass

    def test_condition_wait_releases_held_set(self, lockdep_on):
        """A waiter holding the condition's lock must not contribute order
        edges while parked in wait() — the lock is released for the wait."""
        shard = lockdep.make_rlock("cv.shard")
        cond = lockdep.make_condition(shard, name="cv.shard.cond")
        glob = lockdep.make_lock("cv.global")
        ready, done, waiter_errors = [], [], []

        def waiter():
            try:
                with cond:
                    ready.append(1)
                    while not done:
                        cond.wait(0.5)
                # shard fully released by the with-exit above: taking the
                # global lock here records no shard->global edge, so the
                # notifier's global->shard edge below is not a cycle.  A
                # wait() that failed to untrack would instead record
                # shard->global during the blocked wait and this (or the
                # notifier) would raise LockOrderError.
                with glob:
                    pass
            except BaseException as exc:  # noqa: BLE001 - asserted below
                waiter_errors.append(exc)

        t = threading.Thread(target=waiter)
        t.start()
        for _ in range(100):
            if ready:
                break
            time.sleep(0.01)
        # notifier takes global first, then the condition's shard lock
        with glob:
            with cond:
                done.append(1)
                cond.notify_all()
        t.join(10)
        assert not t.is_alive()
        assert not waiter_errors, waiter_errors

    def test_failed_nonblocking_acquire_not_tracked(self, lockdep_on):
        lk = lockdep.make_lock("nb.L")
        other = lockdep.make_lock("nb.M")
        hold = threading.Thread(
            target=lambda: (lk.acquire(), time.sleep(0.2), lk.release()))
        hold.start()
        time.sleep(0.05)
        with other:
            assert lk.acquire(blocking=False) is False
        hold.join()
        # a failed acquire records the attempt edge but must not leave nb.L
        # in this thread's held set
        with lk:
            pass

    def test_wlm_documented_order_is_acyclic(self, lockdep_on, tmp_path):
        """End-to-end: real queries through WLM/scheduler/exchange/serving
        under lockdep leave an acyclic graph (no exception) with the
        documented shard->global edge present."""
        conn = db.connect(str(tmp_path / "wh"))
        conn.execute("CREATE TABLE t (a INT, b INT)")
        conn.execute("INSERT INTO t VALUES " + ", ".join(
            f"({i % 7}, {i})" for i in range(200)))
        for _ in range(2):
            rows = conn.execute(
                "SELECT a, SUM(b) AS s FROM t GROUP BY a ORDER BY a"
            ).fetchall()
            assert len(rows) == 7
        h = conn.execute_async("SELECT COUNT(*) FROM t")
        assert h.result().fetchall() == [(200,)]
        conn.close()
        g = lockdep.graph_snapshot()
        assert "wlm.global" in g.get("wlm.shard", set())


@pytest.mark.slow
def test_serving_stress_cycle_free_under_lockdep(tmp_path, monkeypatch):
    """32-client mixed workload (shared scans + result cache + WLM + async)
    with every runtime lock tracked: completes with no LockOrderError."""
    monkeypatch.setenv("REPRO_LOCKDEP", "1")
    lockdep.reset()
    from repro.core.session import Warehouse

    wh = Warehouse(str(tmp_path / "wh"), query_workers=32)
    base = db.connect(warehouse=wh)
    cur = base.cursor()
    cur.execute("CREATE TABLE d (k INT, yr INT, w DOUBLE)")
    cur.execute("INSERT INTO d VALUES " +
                ", ".join(f"({i}, {1992 + i % 6}, {i * 0.5})"
                          for i in range(48)))
    cur.execute("CREATE TABLE f (fk INT, rev INT)")
    rng = np.random.default_rng(11)
    fk = rng.integers(0, 48, 4000)
    rev = rng.integers(1, 500, 4000)
    cur.execute("INSERT INTO f VALUES " + ", ".join(
        f"({int(a)}, {int(b)})" for a, b in zip(fk, rev)))

    repeated = ["SELECT yr, SUM(rev) AS s FROM f, d WHERE fk = k GROUP BY yr",
                "SELECT COUNT(*) AS n FROM f"]
    errors = []

    def client(cid):
        try:
            c = db.connect(warehouse=wh)
            r = np.random.default_rng(cid)
            for j in range(3):
                if r.uniform() < 0.5:
                    sql = repeated[int(r.integers(len(repeated)))]
                else:
                    sql = (f"SELECT yr, SUM(rev) AS s FROM f, d WHERE fk = k"
                           f" AND yr >= {1992 + (cid * 3 + j) % 5}"
                           f" GROUP BY yr")
                assert c.execute(sql).fetchall()
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errors.append((cid, exc))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    alive = any(t.is_alive() for t in threads)
    base.close()
    wh.close()
    lockdep.reset()
    assert not alive, "client threads deadlocked"
    inversions = [e for _, e in errors
                  if isinstance(e, lockdep.LockOrderError)
                  or "lock-order inversion" in str(e)]
    assert not inversions, inversions[:3]
    assert not errors, errors[:3]


# ===========================================================================
# plan validator
# ===========================================================================
def _leaf(names):
    from repro.core.optimizer import plan as P

    class _Leaf(P.PlanNode):
        def __init__(self, names):
            self.names = list(names)
            self.inputs = []

        def output_names(self):
            return list(self.names)

        def key(self):
            return f"leaf({','.join(self.names)})"

    return _Leaf(names)


def _dag(vertices, root):
    from repro.core.runtime.dag import TaskDAG

    return TaskDAG(vertices, root)


def _vertex(vid, plan, deps=(), edge_types=None):
    from repro.core.runtime.dag import Vertex

    return Vertex(vid, plan, deps=list(deps), edge_types=edge_types or {})


class TestPlanValidator:
    def test_valid_two_vertex_dag(self):
        from repro.core.runtime.dag import MaterializedNode

        producer = _vertex("v1", _leaf(["a"]))
        root = _vertex("v2", MaterializedNode(["a"], "v1"), deps=["v1"])
        assert validate_dag(_dag({"v1": producer, "v2": root}, "v2")) == []

    def test_unknown_placeholder_tag(self):
        from repro.core.runtime.dag import MaterializedNode

        root = _vertex("v2", MaterializedNode(["a"], "ghost"),
                       deps=["ghost"])
        vs = validate_dag(_dag({"v2": root}, "v2"))
        assert any("unknown vertex 'ghost'" in v for v in vs)

    def test_orphan_vertex_flagged(self):
        from repro.core.runtime.dag import MaterializedNode

        producer = _vertex("v1", _leaf(["a"]))
        orphan = _vertex("v9", _leaf(["z"]))
        root = _vertex("v2", MaterializedNode(["a"], "v1"), deps=["v1"])
        vs = validate_dag(_dag({"v1": producer, "v9": orphan, "v2": root},
                               "v2"))
        assert any("v9" in v and "unreachable" in v for v in vs)
        assert any("v9" in v and "no consumer" in v for v in vs)

    def test_deps_disagree_with_placeholders(self):
        from repro.core.runtime.dag import MaterializedNode

        producer = _vertex("v1", _leaf(["a"]))
        root = _vertex("v2", MaterializedNode(["a"], "v1"), deps=[])
        vs = validate_dag(_dag({"v1": producer, "v2": root}, "v2"))
        assert any("deps missing" in v for v in vs)

    def test_lane_out_of_range_and_uncovered(self):
        from repro.core.optimizer import plan as P
        from repro.core.runtime.dag import MaterializedNode, Vertex

        producer = _vertex("v1", _leaf(["a"]))
        # two lanes declared, readers for lanes 0 and 5 (out of range),
        # lane 1 never read
        u = P.Union([
            MaterializedNode(["a"], "v1", partition=0, num_partitions=2,
                             partition_keys=["a"]),
            MaterializedNode(["a"], "v1", partition=5, num_partitions=2,
                             partition_keys=["a"]),
        ])
        root = Vertex("v2", u, deps=["v1"])
        vs = validate_dag(_dag({"v1": producer, "v2": root}, "v2"))
        assert any("out of range" in v for v in vs)
        assert any("no reader" in v for v in vs)

    def test_leftover_shuffleread(self):
        from repro.core.optimizer import plan as P

        inner = _leaf(["a"])
        sr = P.ShuffleRead(inner, ["a"], 0, 2)
        root = _vertex("v1", sr)
        vs = validate_dag(_dag({"v1": root}, "v1"))
        assert any("ShuffleRead" in v for v in vs)

    def test_plan_cache_aliasing_detected(self):
        from types import SimpleNamespace

        shared = _leaf(["a"])
        root = _vertex("v1", shared)
        cache = SimpleNamespace(
            _lock=threading.Lock(),
            _entries={"k1": SimpleNamespace(plan=shared)})
        vs = validate_dag(_dag({"v1": root}, "v1"), plan_cache=cache)
        assert any("cached plan" in v for v in vs)
        with pytest.raises(PlanValidationError):
            check_dag(_dag({"v1": root}, "v1"), plan_cache=cache)

    def test_check_dag_passes_clean(self):
        root = _vertex("v1", _leaf(["a"]))
        check_dag(_dag({"v1": root}, "v1"))  # must not raise

    def test_config_gate_without_env(self, tmp_path, monkeypatch):
        """debug.validate_plans turns validation on for one session even
        when the env var is unset (and the default leaves it off)."""
        monkeypatch.delenv("REPRO_VALIDATE_PLANS", raising=False)
        from repro.analysis.plan_validator import validation_enabled

        assert not validation_enabled({})
        assert validation_enabled({"debug.validate_plans": True})
        conn = db.connect(str(tmp_path / "wh"),
                          **{"debug.validate_plans": True})
        conn.execute("CREATE TABLE t (a INT)")
        conn.execute("INSERT INTO t VALUES (1), (2)")
        assert conn.execute("SELECT COUNT(*) FROM t").fetchall() == [(2,)]
        conn.close()

    def test_real_plans_validate_including_shuffle_lanes(self, tmp_path):
        """Compiled DAGs from real queries — including lane-expanded
        shuffles — pass the validator (the autouse fixture already has the
        pipeline hook enabled for this test)."""
        conn = db.connect(str(tmp_path / "wh"),
                          **{"shuffle.partitions": 3})
        conn.execute("CREATE TABLE a (k INT, v INT)")
        conn.execute("CREATE TABLE b (k INT, w INT)")
        conn.execute("INSERT INTO a VALUES " + ", ".join(
            f"({i % 11}, {i})" for i in range(300)))
        conn.execute("INSERT INTO b VALUES " + ", ".join(
            f"({i % 11}, {i * 2})" for i in range(300)))
        rows = conn.execute(
            "SELECT a.k, SUM(a.v + b.w) AS s FROM a, b "
            "WHERE a.k = b.k GROUP BY a.k ORDER BY a.k").fetchall()
        assert len(rows) == 11
        conn.close()


# ===========================================================================
# config-key registry
# ===========================================================================
class TestConfigRegistry:
    def test_defaults_derive_from_registry(self):
        from repro.core.config_keys import CONFIG_KEYS, DEFAULT_CONFIG
        from repro.core.session import DEFAULT_CONFIG as SESSION_DEFAULTS

        assert SESSION_DEFAULTS is DEFAULT_CONFIG
        assert set(DEFAULT_CONFIG) == set(CONFIG_KEYS)

    def test_planning_keys_derive_from_registry(self):
        from repro.core.config_keys import PLANNING_KEYS
        from repro.core.pipeline import _PLANNING_KEYS

        assert tuple(_PLANNING_KEYS) == tuple(PLANNING_KEYS)
        assert "shuffle.partitions" in PLANNING_KEYS
        assert "result_cache" not in PLANNING_KEYS  # execution-only knob

    def test_session_warns_on_unknown_key(self, warehouse):
        from repro.core.config_keys import UnknownConfigKeyWarning

        with pytest.warns(UnknownConfigKeyWarning, match="shufle.partitions"):
            warehouse.session(**{"shufle.partitions": 8})
        with warnings.catch_warnings():
            warnings.simplefilter("error", UnknownConfigKeyWarning)
            warehouse.session(**{"shuffle.partitions": 8})

    def test_connect_rejects_unknown_and_mistyped(self, tmp_path):
        with pytest.raises(db.ProgrammingError, match="unknown config"):
            db.connect(str(tmp_path / "w1"), cbo_typo=True)
        with pytest.raises(db.ProgrammingError, match="expects"):
            db.connect(str(tmp_path / "w2"), engine=5)
        conn = db.connect(str(tmp_path / "w3"),
                          broadcast_threshold_rows=np.int64(100))
        conn.close()
