"""Federation: capability-negotiated DataSource/catalog API (paper §6).

Covers the redesigned surface: ``CREATE CATALOG`` + three-part names with
lazy remote-schema discovery, piecewise pushdown negotiation (each kind
toggleable, residuals evaluated locally), split-parallel streaming scans
through the exchange layer, the batched Writer path, and the SerDe
union-of-keys fix.
"""
import time

import numpy as np
import pytest

from repro.core.runtime.vector import VectorBatch

PUSH_OFF = {
    "federation.push_filters": False,
    "federation.push_projection": False,
    "federation.push_aggregate": False,
    "federation.push_limit": False,
}


def _rounded(rows):
    return sorted(
        tuple(round(x, 6) if isinstance(x, float) else x for x in r)
        for r in rows
    )


@pytest.fixture()
def druid_source(warehouse):
    rng = np.random.default_rng(3)
    dr = warehouse.handlers.get("druid")
    dr.store.create_datasource("my_druid_source", VectorBatch({
        "__time": np.array([f"2017-{1 + i % 12:02d}-01" for i in range(3000)]),
        "d1": np.array([f"u{i % 7}" for i in range(3000)]),
        "m1": rng.uniform(0, 10, 3000),
    }))
    s = warehouse.session()
    s.execute(
        "CREATE EXTERNAL TABLE druid_table_1 STORED BY"
        " 'org.apache.hadoop.hive.druid.DruidStorageHandler'"
        " TBLPROPERTIES ('druid.datasource' = 'my_druid_source')")
    return warehouse


@pytest.fixture()
def mem_catalog(warehouse):
    """A mounted memtable catalog with one table of 3 columns."""
    s = warehouse.session()
    s.execute("CREATE CATALOG mem USING memtable")
    h = warehouse.catalogs.get("mem").handler
    rng = np.random.default_rng(11)
    h.load("t", VectorBatch({
        "a": np.arange(2000),
        "b": rng.uniform(0, 1, 2000).round(6),
        "c": np.array([f"g{i % 5}" for i in range(2000)]),
    }))
    return warehouse


# ===========================================================================
# STORED BY handlers, rebuilt on the new API (back-compat surface)
# ===========================================================================
def test_schema_inference_from_druid(druid_source):
    desc = druid_source.hms.get_table("druid_table_1")
    assert dict(desc.schema)["m1"] == "DOUBLE"
    assert dict(desc.schema)["d1"] == "STRING"


def test_groupby_pushdown_figure6(druid_source):
    """The Figure-6 query: groupBy JSON with limitSpec pushed to Druid."""
    s = druid_source.session()
    r = s.execute("SELECT d1, SUM(m1) AS sm FROM druid_table_1"
                  " GROUP BY d1 ORDER BY sm DESC LIMIT 3")
    pushed = r.info["federated_pushdown"]["druid_table_1"]["pushed"]
    assert pushed["aggregate"] == "full"  # single segment -> fully absorbed
    assert pushed["limit"] == "full"
    dr = druid_source.handlers.get("druid")
    q = dr.store.queries_served[-1]
    assert q["queryType"] == "groupBy"
    assert q["limitSpec"]["limit"] == 3
    assert q["limitSpec"]["columns"][0]["direction"] == "descending"
    # correctness vs local compute
    seg = VectorBatch.concat([x.batch for x in dr.store.datasources["my_druid_source"]])
    import collections

    agg = collections.defaultdict(float)
    for d, m in zip(seg.cols["d1"], seg.cols["m1"]):
        agg[d] += m
    exp = sorted(agg.items(), key=lambda kv: -kv[1])[:3]
    assert [(a, round(b, 6)) for a, b in r.rows] == \
        [(a, round(b, 6)) for a, b in exp]


def test_druid_partial_aggregate_multi_segment(warehouse):
    """Multiple segments: per-segment partial aggregates stream in parallel
    and the local Aggregate merges them (partial pushdown, not a bypass)."""
    rng = np.random.default_rng(5)
    dr = warehouse.handlers.get("druid")
    dr.store.segment_rows = 500
    dr.store.create_datasource("seg_src", VectorBatch({
        "d1": np.array([f"u{i % 7}" for i in range(3000)]),
        "m1": rng.uniform(0, 10, 3000),
    }))
    s = warehouse.session(result_cache=False)
    s.execute("CREATE EXTERNAL TABLE segt STORED BY 'druid'"
              " TBLPROPERTIES ('druid.datasource' = 'seg_src')")
    r = s.execute("SELECT d1, SUM(m1) sm, COUNT(*) c FROM segt GROUP BY d1"
                  " ORDER BY d1")
    report = r.info["federated_pushdown"]["segt"]
    assert report["pushed"]["aggregate"] == "partial"
    assert report["residual"]["aggregate"] == "merge"
    off = warehouse.session(result_cache=False, **PUSH_OFF)
    r_off = off.execute("SELECT d1, SUM(m1) sm, COUNT(*) c FROM segt"
                        " GROUP BY d1 ORDER BY d1")
    assert _rounded(r.rows) == _rounded(r_off.rows)


def test_filter_pushdown_to_druid(druid_source):
    s = druid_source.session()
    r = s.execute("SELECT d1, m1 FROM druid_table_1 WHERE d1 = 'u3'")
    report = r.info["federated_pushdown"]["druid_table_1"]
    assert report["pushed"]["filters"] == 1
    assert report["residual"] == {}
    assert all(d == "u3" for d, _ in r.rows)


def test_druid_join_with_native_table(druid_source):
    s = druid_source.session()
    s.execute("CREATE TABLE users (uid STRING, region STRING)")
    s.execute("INSERT INTO users VALUES ('u1', 'emea'), ('u3', 'apac')")
    r = s.execute("""SELECT region, SUM(m1) s FROM druid_table_1, users
                     WHERE d1 = uid GROUP BY region ORDER BY region""")
    assert [row[0] for row in r.rows] == ["apac", "emea"]


def test_jdbc_sql_generation_pushdown(warehouse):
    jd = warehouse.handlers.get("jdbc")
    rng = np.random.default_rng(4)
    jd.load_table("remote_t", VectorBatch({
        "a": np.arange(500), "b": rng.uniform(0, 1, 500)}))
    s = warehouse.session()
    s.execute("CREATE EXTERNAL TABLE jt (a INT, b DOUBLE) STORED BY 'jdbc'"
              " TBLPROPERTIES ('jdbc.table'='remote_t')")
    r = s.execute("SELECT SUM(b) sb, COUNT(*) c FROM jt WHERE a BETWEEN 10 AND 99")
    report = r.info["federated_pushdown"]["jt"]
    assert report["pushed"] == {"filters": 1, "aggregate": "full"}
    sql = jd.queries_served[-1]
    assert "GROUP BY" not in sql and "WHERE" in sql and "SUM" in sql
    assert r.rows[0][1] == 90


def test_jdbc_schema_inference(warehouse):
    jd = warehouse.handlers.get("jdbc")
    jd.load_table("inferme", VectorBatch({"x": np.arange(3),
                                          "y": np.array(["a", "b", "c"])}))
    s = warehouse.session()
    s.execute("CREATE EXTERNAL TABLE it STORED BY 'jdbc'"
              " TBLPROPERTIES ('jdbc.table'='inferme')")
    desc = warehouse.hms.get_table("it")
    assert dict(desc.schema) == {"x": "BIGINT", "y": "STRING"}


def test_insert_into_druid_table(druid_source):
    """Output format: the batched Writer path (write_batch/commit)."""
    s = druid_source.session()
    s.execute("CREATE EXTERNAL TABLE druid_table_2 (__time STRING,"
              " dim1 VARCHAR(20), m1 DOUBLE) STORED BY 'druid'")
    s.execute("INSERT INTO druid_table_2 VALUES ('2017-01-01', 'x', 1.5),"
              " ('2017-01-02', 'y', 2.5)")
    r = s.execute("SELECT SUM(m1) FROM druid_table_2")
    assert abs(r.rows[0][0] - 4.0) < 1e-9


def test_metastore_hook_notifications(druid_source):
    events = [e for _, e, _ in druid_source.hms.notifications()]
    assert "CREATE_TABLE" in events


# ===========================================================================
# SerDe: union of keys + null fill (heterogeneous external rows)
# ===========================================================================
def test_serde_union_of_keys_null_fill():
    from repro.core.federation.handler import SerDe

    rows = [{"a": 1, "b": 2.5}, {"a": 2, "c": "x"}, {"b": 7.0, "c": "y"}]
    batch = SerDe().deserialize(rows)
    assert set(batch.column_names) == {"a", "b", "c"}  # not just rows[0]
    assert batch.num_rows == 3
    a = batch.cols["a"]
    assert a[0] == 1 and a[1] == 2 and np.isnan(a[2])
    b = batch.cols["b"]
    assert b[0] == 2.5 and np.isnan(b[1]) and b[2] == 7.0
    assert batch.cols["c"].tolist() == ["", "x", "y"]


def test_memtable_load_rows_routes_through_serde(warehouse):
    s = warehouse.session()
    s.execute("CREATE CATALOG hetero USING memtable")
    h = warehouse.catalogs.get("hetero").handler
    h.load("ev", [{"k": 1, "v": 10.0}, {"k": 2}, {"k": 3, "v": 30.0}])
    r = s.execute("SELECT SUM(v) sv, COUNT(*) c FROM hetero.default.ev")
    assert r.rows[0] == (40.0, 3)  # NaN null-fill skipped by SUM


# ===========================================================================
# catalogs: CREATE CATALOG, three-part names, lazy discovery, persistence
# ===========================================================================
def test_catalog_three_part_names_and_discovery(mem_catalog):
    s = mem_catalog.session(result_cache=False)
    r = s.execute("SELECT a, b FROM mem.default.t WHERE a < 5 ORDER BY a")
    assert [row[0] for row in r.rows] == [0, 1, 2, 3, 4]
    # two-part name goes through the connector's default schema
    r2 = s.execute("SELECT a FROM mem.t WHERE a >= 1998 ORDER BY a")
    assert [row[0] for row in r2.rows] == [1998, 1999]
    # lazy discovery cached the TableDesc on the catalog
    cat = mem_catalog.catalogs.get("mem")
    assert "default.t" in cat._descs
    assert cat.list_tables() == ["t"]


def test_catalog_alias_and_join_with_native(mem_catalog):
    s = mem_catalog.session(result_cache=False)
    s.execute("CREATE TABLE grp (g STRING, w INT)")
    s.execute("INSERT INTO grp VALUES ('g0', 10), ('g3', 20)")
    r = s.execute("""SELECT grp.g, COUNT(*) n FROM mem.default.t x, grp
                     WHERE x.c = grp.g GROUP BY grp.g ORDER BY grp.g""")
    assert [row[0] for row in r.rows] == ["g0", "g3"]
    assert all(n == 400 for _, n in r.rows)


def test_catalog_ddl_api_and_persistence(tmp_path):
    import repro.api as db
    from repro.core.session import Warehouse

    whdir = str(tmp_path / "wh")
    conn = db.connect(whdir)
    conn.execute("CREATE CATALOG sales USING jdbc")
    conn.execute("CREATE CATALOG events USING memtable WITH (latency_s = '0')")
    assert conn.catalogs() == {"events": "memtable", "sales": "jdbc"}
    # each jdbc catalog is its own connector instance, not the global one
    jd = conn.warehouse.catalogs.get("sales").handler
    assert jd is not conn.warehouse.handlers.get("jdbc")
    jd.load_table("customers", VectorBatch({
        "id": np.arange(5), "name": np.array(list("abcde"))}))
    cur = conn.execute("SELECT name FROM sales.main.customers WHERE id = 3")
    assert cur.fetchall() == [("d",)]
    conn.execute("DROP CATALOG events")
    assert conn.catalogs() == {"sales": "jdbc"}
    conn.close()

    # catalog definitions persist in the metastore across reopen
    wh2 = Warehouse(whdir)
    assert wh2.catalogs.names() == ["sales"]
    assert wh2.catalogs.get("sales").connector == "jdbc"
    wh2.close()


def test_unknown_catalog_and_table_errors(mem_catalog):
    s = mem_catalog.session()
    with pytest.raises(Exception, match="unknown catalog"):
        s.execute("SELECT * FROM nope.default.t")
    with pytest.raises(Exception, match="no table"):
        s.execute("SELECT * FROM mem.default.missing")


# ===========================================================================
# capability matrix: each pushdown kind on/off x residual correctness
# ===========================================================================
# (gate, query, expectation key/value); the LIMIT probe runs without a
# WHERE clause because a limit may not jump below an unpushed filter
FILTER_Q = "SELECT a, b FROM mem.default.t WHERE a < 1200 AND b < 0.9"
LIMIT_Q = "SELECT a, b FROM mem.default.t LIMIT 400"


@pytest.mark.parametrize("gate,query", [
    ("federation.push_filters", FILTER_Q),
    ("federation.push_projection", FILTER_Q),
    ("federation.push_limit", LIMIT_Q),
])
def test_capability_matrix_memtable(mem_catalog, gate, query):
    base = mem_catalog.session(result_cache=False, **PUSH_OFF)
    r_off = base.execute(query)
    assert r_off.info["federated_pushdown"]["mem.default.t"]["pushed"] == {}

    on = mem_catalog.session(result_cache=False,
                             **{**PUSH_OFF, gate: True})
    r_on = on.execute(query)
    pushed = r_on.info["federated_pushdown"]["mem.default.t"]["pushed"]
    kind = gate.split(".")[-1].replace("push_", "")
    if kind == "filters":
        assert pushed.get("filters") == 2
    elif kind == "projection":
        assert pushed.get("projection") == ["a", "b"]
    else:
        assert pushed.get("limit") == "partial"
    if kind == "limit":
        # a LIMIT result set is not deterministic; counts must agree
        assert r_on.num_rows == r_off.num_rows == 400
        assert all(a < 2000 for a, _ in r_on.rows)
    else:
        # residual correctness: rows identical to pushdown-off
        assert _rounded(r_on.rows) == _rounded(r_off.rows)
        full_on = mem_catalog.session(result_cache=False)
        assert _rounded(full_on.execute(query).rows) == _rounded(r_off.rows)


@pytest.mark.parametrize("enabled", [True, False])
def test_capability_matrix_aggregate_jdbc(warehouse, enabled):
    jd = warehouse.handlers.get("jdbc")
    rng = np.random.default_rng(9)
    jd.load_table("m", VectorBatch({
        "g": np.array([f"k{i % 4}" for i in range(300)]),
        "v": rng.uniform(0, 5, 300).round(4)}))
    s = warehouse.session(result_cache=False,
                          **{"federation.push_aggregate": enabled})
    s.execute("CREATE EXTERNAL TABLE magg (g STRING, v DOUBLE)"
              " STORED BY 'jdbc' TBLPROPERTIES ('jdbc.table'='m')")
    r = s.execute("SELECT g, SUM(v) sv, MIN(v) mv FROM magg GROUP BY g"
                  " ORDER BY g")
    pushed = r.info["federated_pushdown"]["magg"]["pushed"]
    assert ("aggregate" in pushed) == enabled
    exp = {}
    raw = jd.conn.execute('SELECT "g", "v" FROM "m"').fetchall()
    for g, v in raw:
        lo, sm = exp.get(g, (float("inf"), 0.0))
        exp[g] = (min(lo, v), sm + v)
    expect = sorted((g, round(sm, 6), round(lo, 6))
                    for g, (lo, sm) in exp.items())
    assert [(g, round(sv, 6), round(mv, 6)) for g, sv, mv in r.rows] == expect


def test_partial_filter_residual_parity(warehouse):
    """One conjunct translates, one does not: the residual is evaluated
    locally and results match pushdown-off exactly."""
    jd = warehouse.handlers.get("jdbc")
    jd.load_table("pr", VectorBatch({
        "a": np.arange(200), "s": np.array([f"V{i % 10}" for i in range(200)])}))
    s = warehouse.session(result_cache=False)
    s.execute("CREATE EXTERNAL TABLE prt (a INT, s STRING) STORED BY 'jdbc'"
              " TBLPROPERTIES ('jdbc.table'='pr')")
    q = "SELECT a, s FROM prt WHERE a < 100 AND lower(s) = 'v3'"
    r = s.execute(q)
    report = r.info["federated_pushdown"]["prt"]
    assert report["pushed"]["filters"] == 1      # a < 100 -> SQL
    assert report["residual"]["filters"] == 1    # lower(s) = 'v3' -> local
    off = warehouse.session(result_cache=False, **PUSH_OFF)
    assert _rounded(r.rows) == _rounded(off.execute(q).rows)
    assert r.num_rows == 10


def test_explain_shows_pushed_vs_residual(warehouse):
    jd = warehouse.handlers.get("jdbc")
    jd.load_table("ex", VectorBatch({
        "a": np.arange(50), "s": np.array([f"V{i % 5}" for i in range(50)])}))
    s = warehouse.session()
    s.execute("CREATE EXTERNAL TABLE ext (a INT, s STRING) STORED BY 'jdbc'"
              " TBLPROPERTIES ('jdbc.table'='ex')")
    text = s.explain("SELECT a FROM ext WHERE a < 10 AND lower(s) = 'v1'")
    assert "pushed=filters:1" in text          # on the FederatedScan node
    assert "Filter[" in text                   # the residual, kept local
    assert "lower" in text


# ===========================================================================
# streaming: first batch before the connector finishes; splits in parallel
# ===========================================================================
def test_streaming_first_batch_before_producer_finishes(warehouse):
    import repro.api as db

    s = warehouse.session()
    s.execute("CREATE CATALOG slow USING memtable"
              " WITH (latency_s = '0.01', batch_rows = '50')")
    h = warehouse.catalogs.get("slow").handler
    h.load("t", VectorBatch({"a": np.arange(4000),
                             "b": np.arange(4000) * 0.5}))
    # 2 splits + union + root on the 4 LLAP executors: the root vertex
    # streams concurrently with the split readers
    conn = db.connect(warehouse=warehouse, result_cache=False,
                      **{"federation.splits": 2})
    handle = conn.execute_async("SELECT a, b FROM slow.default.t")
    t_first = None
    rows = 0
    for batch in handle.fetch_stream(batch_rows=50):
        if t_first is None:
            t_first = time.monotonic()
            state_at_first = handle.state
        rows += len(batch)
    handle.result(60)
    assert rows == 4000
    # the connector was still producing when the first batch reached us
    assert h.last_produced_at() is not None
    assert t_first < h.last_produced_at()
    assert state_at_first == "RUNNING"
    # splits executed concurrently through the exchange layer
    assert h.peak_active_readers >= 2
    # ... and the DAG really fanned out one vertex per split
    p = handle.poll()
    assert p["vertices_total"] >= 4
    conn.close()


def test_split_parallel_parity_and_cancellation(warehouse):
    import repro.api as db

    s = warehouse.session()
    s.execute("CREATE CATALOG par USING memtable"
              " WITH (latency_s = '0.005', batch_rows = '100')")
    h = warehouse.catalogs.get("par").handler
    h.load("t", VectorBatch({"a": np.arange(3000)}))
    conn = db.connect(warehouse=warehouse, result_cache=False)
    # parity across split counts
    one = db.connect(warehouse=warehouse, result_cache=False,
                     **{"federation.splits": 1})
    q = "SELECT a FROM par.default.t WHERE a % 7 = 0"
    assert sorted(conn.execute(q).fetchall()) == \
        sorted(one.execute(q).fetchall())
    # cancel is observed at batch boundaries inside split readers
    handle = conn.execute_async("SELECT a FROM par.default.t")
    handle.cancel()
    with pytest.raises(db.QueryCancelledError):
        handle.result(30)
    one.close()
    conn.close()


def test_aggregate_over_expression_stays_local(warehouse):
    """SUM(v + 1): the binder pre-projects a computed column; that synthetic
    name is NOT a remote column, so the aggregate must stay local (pushing
    it used to generate SUM("aa_N") and silently return 0 via sqlite's
    string-literal fallback)."""
    jd = warehouse.handlers.get("jdbc")
    jd.load_table("r", VectorBatch({
        "g": np.array(["a", "a", "b", "b"]), "v": np.array([2.0, 3.0, 4.0, 3.0])}))
    s = warehouse.session(result_cache=False)
    s.execute("CREATE EXTERNAL TABLE rt (g STRING, v DOUBLE) STORED BY 'jdbc'"
              " TBLPROPERTIES ('jdbc.table'='r')")
    r = s.execute("SELECT g, SUM(v + 1) s2 FROM rt GROUP BY g ORDER BY g")
    assert "aggregate" not in \
        r.info["federated_pushdown"]["rt"]["pushed"]
    assert [(g, round(x, 6)) for g, x in r.rows] == [("a", 7.0), ("b", 9.0)]
    # same shape on druid: must not crash, must not push
    dr = warehouse.handlers.get("druid")
    dr.store.create_datasource("rexpr", VectorBatch({
        "g": np.array(["a", "a", "b"]), "v": np.array([1.0, 2.0, 3.0])}))
    s.execute("CREATE EXTERNAL TABLE drt STORED BY 'druid'"
              " TBLPROPERTIES ('druid.datasource'='rexpr')")
    r = s.execute("SELECT g, SUM(v * 2) m FROM drt GROUP BY g ORDER BY g")
    assert [(g, round(x, 6)) for g, x in r.rows] == [("a", 6.0), ("b", 6.0)]


def test_group_by_expression_stays_local(mem_catalog):
    """GROUP BY (a % 3): synthetic group-key columns must not push."""
    s = mem_catalog.session(result_cache=False)
    r = s.execute("SELECT a % 3 AS k, COUNT(*) n FROM mem.default.t"
                  " GROUP BY a % 3 ORDER BY k")
    assert [row[0] for row in r.rows] == [0, 1, 2]
    assert sum(row[1] for row in r.rows) == 2000
