"""Federation: storage handlers + Calcite-style pushdown (paper §6)."""
import numpy as np
import pytest

from repro.core.runtime.vector import VectorBatch


@pytest.fixture()
def druid_source(warehouse):
    rng = np.random.default_rng(3)
    dr = warehouse.handlers.get("druid")
    dr.store.create_datasource("my_druid_source", VectorBatch({
        "__time": np.array([f"2017-{1 + i % 12:02d}-01" for i in range(3000)]),
        "d1": np.array([f"u{i % 7}" for i in range(3000)]),
        "m1": rng.uniform(0, 10, 3000),
    }))
    s = warehouse.session()
    s.execute(
        "CREATE EXTERNAL TABLE druid_table_1 STORED BY"
        " 'org.apache.hadoop.hive.druid.DruidStorageHandler'"
        " TBLPROPERTIES ('druid.datasource' = 'my_druid_source')")
    return warehouse


def test_schema_inference_from_druid(druid_source):
    desc = druid_source.hms.get_table("druid_table_1")
    assert dict(desc.schema)["m1"] == "DOUBLE"
    assert dict(desc.schema)["d1"] == "STRING"


def test_groupby_pushdown_figure6(druid_source):
    """The Figure-6 query: groupBy JSON with limitSpec pushed to Druid."""
    s = druid_source.session()
    r = s.execute("SELECT d1, SUM(m1) AS sm FROM druid_table_1"
                  " GROUP BY d1 ORDER BY sm DESC LIMIT 3")
    assert r.info.get("federated_pushdown") == {"druid_table_1": "groupBy"}
    dr = druid_source.handlers.get("druid")
    q = dr.store.queries_served[-1]
    assert q["queryType"] == "groupBy"
    assert q["limitSpec"]["limit"] == 3
    assert q["limitSpec"]["columns"][0]["direction"] == "descending"
    # correctness vs local compute
    seg = VectorBatch.concat([x.batch for x in dr.store.datasources["my_druid_source"]])
    import collections

    agg = collections.defaultdict(float)
    for d, m in zip(seg.cols["d1"], seg.cols["m1"]):
        agg[d] += m
    exp = sorted(agg.items(), key=lambda kv: -kv[1])[:3]
    assert [(a, round(b, 6)) for a, b in r.rows] == \
        [(a, round(b, 6)) for a, b in exp]


def test_filter_pushdown_to_druid(druid_source):
    s = druid_source.session()
    r = s.execute("SELECT d1, m1 FROM druid_table_1 WHERE d1 = 'u3'")
    assert r.info.get("federated_pushdown") == {"druid_table_1": "scan"}
    assert all(d == "u3" for d, _ in r.rows)


def test_druid_join_with_native_table(druid_source):
    s = druid_source.session()
    s.execute("CREATE TABLE users (uid STRING, region STRING)")
    s.execute("INSERT INTO users VALUES ('u1', 'emea'), ('u3', 'apac')")
    r = s.execute("""SELECT region, SUM(m1) s FROM druid_table_1, users
                     WHERE d1 = uid GROUP BY region ORDER BY region""")
    assert [row[0] for row in r.rows] == ["apac", "emea"]


def test_jdbc_sql_generation_pushdown(warehouse):
    jd = warehouse.handlers.get("jdbc")
    rng = np.random.default_rng(4)
    jd.load_table("remote_t", VectorBatch({
        "a": np.arange(500), "b": rng.uniform(0, 1, 500)}))
    s = warehouse.session()
    s.execute("CREATE EXTERNAL TABLE jt (a INT, b DOUBLE) STORED BY 'jdbc'"
              " TBLPROPERTIES ('jdbc.table'='remote_t')")
    r = s.execute("SELECT SUM(b) sb, COUNT(*) c FROM jt WHERE a BETWEEN 10 AND 99")
    assert r.info.get("federated_pushdown") == {"jt": "sql"}
    sql = jd.queries_served[-1]
    assert "GROUP BY" not in sql and "WHERE" in sql and "SUM" in sql
    assert r.rows[0][1] == 90


def test_jdbc_schema_inference(warehouse):
    jd = warehouse.handlers.get("jdbc")
    jd.load_table("inferme", VectorBatch({"x": np.arange(3),
                                          "y": np.array(["a", "b", "c"])}))
    s = warehouse.session()
    s.execute("CREATE EXTERNAL TABLE it STORED BY 'jdbc'"
              " TBLPROPERTIES ('jdbc.table'='inferme')")
    desc = warehouse.hms.get_table("it")
    assert dict(desc.schema) == {"x": "BIGINT", "y": "STRING"}


def test_insert_into_druid_table(druid_source):
    """Output format: Hive writes data sources into Druid (paper §6.1)."""
    s = druid_source.session()
    s.execute("CREATE EXTERNAL TABLE druid_table_2 (__time STRING,"
              " dim1 VARCHAR(20), m1 DOUBLE) STORED BY 'druid'")
    s.execute("INSERT INTO druid_table_2 VALUES ('2017-01-01', 'x', 1.5),"
              " ('2017-01-02', 'y', 2.5)")
    r = s.execute("SELECT SUM(m1) FROM druid_table_2")
    assert abs(r.rows[0][0] - 4.0) < 1e-9


def test_metastore_hook_notifications(druid_source):
    events = [e for _, e, _ in druid_source.hms.notifications()]
    assert "CREATE_TABLE" in events
