"""Dry-run machinery: sharding resolver, HLO analysis, small-mesh compile.

The full 33-cell x 2-mesh matrix runs via
``python -m repro.launch.dryrun --all --both-meshes`` (see EXPERIMENTS.md);
here we verify the machinery on an 8-device debug mesh in a subprocess.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_resolve_pspec_divisibility():
    import jax

    from repro.launch.sharding import resolve_pspec

    mesh = jax.make_mesh((1,), ("model",))  # single device: everything divides by 1
    p = resolve_pspec(("model", "data"), (40, 128), mesh)
    assert p[0] == "model"

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}

    # 40 heads don't divide model=16 -> dropped; 17408 does
    p = resolve_pspec(("model",), (40,), FakeMesh())
    assert p == (None,) if len(p) else True
    p = resolve_pspec(("data", "model"), (5120, 17408), FakeMesh())
    assert tuple(p) == ("data", "model")
    # expand_data maps data -> (pod, data) for batch trees
    p = resolve_pspec(("data",), (128,), FakeMesh(), expand_data=True)
    assert p[0] == ("pod", "data")
    # never reuse an axis twice
    p = resolve_pspec(("model", "model"), (64, 64), FakeMesh())
    assert p[1] is None


def test_hlo_analysis_counts_loops():
    from repro.launch.hlo_analysis import analyze_hlo

    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    hc = analyze_hlo(hlo)
    assert hc.flops == 2 * 8 * 8 * 8 * 5  # dot x trip count 5
    assert hc.collective_bytes == 8 * 8 * 4 * 5
    assert hc.collective_by_type == {"all-reduce": 8 * 8 * 4 * 5}


@pytest.mark.slow
def test_debug_mesh_dryrun_cells():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out_dir = os.path.join(REPO, "experiments", "dryrun_test")
    for arch, shape in [("mamba2-130m", "train_4k"), ("qwen3-14b", "decode_32k")]:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--debug-mesh", "--out-dir", out_dir],
            capture_output=True, text=True, env=env, timeout=560,
        )
        assert r.returncode == 0, r.stdout + r.stderr[-2000:]
        assert "OK" in r.stdout
    files = os.listdir(out_dir)
    assert len(files) >= 2
    with open(os.path.join(out_dir, files[0])) as f:
        art = json.load(f)
    rf = art["roofline"]
    assert rf["hlo_flops"] > 0 and rf["bottleneck"] in (
        "compute", "memory", "collective")


def test_artifacts_exist_for_all_cells():
    """The full production-mesh matrix must have been generated."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("run `python -m repro.launch.dryrun --all --both-meshes`")
    names = os.listdir(d)
    single = [n for n in names if "__16x16" in n and "debug" not in n]
    multi = [n for n in names if "__2x16x16" in n]
    assert len(single) >= 33, f"expected 33 single-pod cells, got {len(single)}"
    assert len(multi) >= 33, f"expected 33 multi-pod cells, got {len(multi)}"
