"""End-to-end SQL execution correctness vs numpy references (paper §3.1)."""
import collections

import numpy as np
import pytest
from _hypothesis_compat import HealthCheck, given, settings, st


def q(session, sql):
    return session.execute(sql)


def test_join_agg_orderby(star_schema):
    s = star_schema.session()
    r = q(s, """SELECT i_category, SUM(ss_price * ss_qty) AS rev, COUNT(*) c
                FROM store_sales, item WHERE ss_item_sk = i_item_sk
                GROUP BY i_category ORDER BY rev DESC""")
    # numpy oracle
    hms = star_schema.hms
    from repro.core.acid import AcidTable
    snap = hms.get_snapshot()
    ss = AcidTable(hms.get_table("store_sales"), hms).read_all(
        hms.writeid_list("store_sales", snap))
    it = AcidTable(hms.get_table("item"), hms).read_all(
        hms.writeid_list("item", snap))
    cat = dict(zip(it.cols["i_item_sk"].tolist(), it.cols["i_category"].tolist()))
    rev, cnt = collections.defaultdict(float), collections.Counter()
    for k, p, n in zip(ss.cols["ss_item_sk"], ss.cols["ss_price"], ss.cols["ss_qty"]):
        rev[cat[k]] += p * n
        cnt[cat[k]] += 1
    exp = sorted(((c, v, cnt[c]) for c, v in rev.items()), key=lambda t: -t[1])
    got = [(a, round(b, 6), c) for a, b, c in r.rows]
    assert got == [(a, round(b, 6), c) for a, b, c in exp]


def test_correlated_scalar_subquery(star_schema):
    s = star_schema.session()
    r = q(s, """SELECT i.i_item_sk,
                (SELECT MAX(ss_price) FROM store_sales ss
                 WHERE ss.ss_item_sk = i.i_item_sk) mx
                FROM item i ORDER BY i.i_item_sk LIMIT 10""")
    hms = star_schema.hms
    from repro.core.acid import AcidTable
    snap = hms.get_snapshot()
    ss = AcidTable(hms.get_table("store_sales"), hms).read_all(
        hms.writeid_list("store_sales", snap))
    mx = collections.defaultdict(float)
    for k, p in zip(ss.cols["ss_item_sk"], ss.cols["ss_price"]):
        mx[k] = max(mx[k], p)
    for k, v in r.rows:
        if not np.isnan(v):
            assert abs(v - mx[k]) < 1e-9


def test_exists_and_in_subqueries(star_schema):
    s = star_schema.session()
    r1 = q(s, """SELECT COUNT(*) FROM item WHERE EXISTS
                 (SELECT 1 FROM store_sales WHERE ss_item_sk = i_item_sk
                  AND ss_price > 99)""")
    r2 = q(s, """SELECT COUNT(*) FROM item WHERE i_item_sk IN
                 (SELECT ss_item_sk FROM store_sales WHERE ss_price > 99)""")
    assert r1.rows == r2.rows


def test_set_operations(star_schema):
    s = star_schema.session()
    a = q(s, "SELECT i_category FROM item WHERE i_price > 50 "
             "INTERSECT SELECT i_category FROM item WHERE i_price <= 50")
    b = q(s, "SELECT DISTINCT i_category FROM item")
    assert 0 < a.num_rows <= b.num_rows
    c = q(s, "SELECT i_category FROM item UNION SELECT i_category FROM item")
    assert c.num_rows == b.num_rows


def test_window_functions(star_schema):
    s = star_schema.session()
    r = q(s, """SELECT i_category, i_price,
                rank() OVER (PARTITION BY i_category ORDER BY i_price DESC) rk
                FROM item""")
    by_cat = collections.defaultdict(list)
    for cat, price, rk in r.rows:
        by_cat[cat].append((price, rk))
    for cat, vals in by_cat.items():
        vals.sort(key=lambda t: -t[0])
        assert vals[0][1] == 1
        for (p1, r1_), (p2, r2_) in zip(vals, vals[1:]):
            assert r2_ >= r1_


def test_grouping_sets(star_schema):
    s = star_schema.session()
    r = q(s, """SELECT i_category, d_year, SUM(ss_price) s
                FROM store_sales, item, date_dim
                WHERE ss_item_sk = i_item_sk AND ss_date_sk = d_date_sk
                GROUP BY GROUPING SETS ((i_category, d_year), (i_category), ())""")
    fine = [row for row in r.rows if row[0] != "" and not _isnan(row[1])]
    cat_rows = [row for row in r.rows if row[0] != "" and _isnan(row[1])]
    total_rows = [row for row in r.rows if row[0] == ""]
    assert len(total_rows) == 1
    assert abs(sum(x[2] for x in fine) - total_rows[0][2]) < 1e-6
    assert abs(sum(x[2] for x in cat_rows) - total_rows[0][2]) < 1e-6


def _isnan(x):
    try:
        return np.isnan(x)
    except TypeError:
        return False


def test_update_delete_merge_roundtrip(star_schema):
    s = star_schema.session()
    before = q(s, "SELECT SUM(i_price) FROM item").rows[0][0]
    s.execute("UPDATE item SET i_price = i_price + 10 WHERE i_category = 'Books'")
    n_books = q(s, "SELECT COUNT(*) FROM item WHERE i_category = 'Books'").rows[0][0]
    after = q(s, "SELECT SUM(i_price) FROM item").rows[0][0]
    assert abs(after - before - 10 * n_books) < 1e-6
    s.execute("DELETE FROM item WHERE i_category = 'Toys'")
    assert q(s, "SELECT COUNT(*) FROM item WHERE i_category = 'Toys'").rows[0][0] == 0
    s.execute("CREATE TABLE updates (k INT, price DOUBLE)")
    s.execute("INSERT INTO updates VALUES (0, 1.5), (1, 2.5), (9999, 3.5)")
    r = s.execute("""MERGE INTO item USING updates ON i_item_sk = k
                     WHEN MATCHED THEN UPDATE SET i_price = price
                     WHEN NOT MATCHED THEN INSERT (i_item_sk, i_category, i_price)
                     VALUES (k, 'New', price)""")
    assert r.info["updated"] == 2 and r.info["inserted"] == 1
    assert q(s, "SELECT i_price FROM item WHERE i_item_sk = 0").rows[0][0] == 1.5
    assert q(s, "SELECT i_category FROM item WHERE i_item_sk = 9999").rows[0][0] == "New"


def test_multi_table_write_single_txn(star_schema):
    """Writing two tables under one transaction (multi-insert, §3.2)."""
    wh = star_schema
    hms = wh.hms
    from repro.core.acid import AcidTable
    from repro.core.runtime.vector import VectorBatch

    hms.create_table("t1", [("a", "INT")])
    hms.create_table("t2", [("a", "INT")])
    tx = hms.open_txn()
    AcidTable(hms.get_table("t1"), hms).insert(tx, VectorBatch({"a": np.array([1])}))
    AcidTable(hms.get_table("t2"), hms).insert(tx, VectorBatch({"a": np.array([2])}))
    hms.commit_txn(tx)
    s = wh.session()
    assert q(s, "SELECT COUNT(*) FROM t1").rows[0][0] == 1
    assert q(s, "SELECT COUNT(*) FROM t2").rows[0][0] == 1


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(
    data=st.lists(st.tuples(st.integers(0, 20), st.integers(-50, 50)),
                  min_size=1, max_size=60),
    threshold=st.integers(-40, 40),
)
def test_property_filter_group_matches_numpy(tmp_path_factory, data, threshold):
    from repro.core.session import Warehouse

    wh = Warehouse(str(tmp_path_factory.mktemp("wh")))
    s = wh.session()
    s.execute("CREATE TABLE r (g INT, x INT)")
    values = ", ".join(f"({g}, {x})" for g, x in data)
    s.execute(f"INSERT INTO r VALUES {values}")
    r = s.execute(
        f"SELECT g, SUM(x) s, COUNT(*) c FROM r WHERE x > {threshold}"
        " GROUP BY g ORDER BY g")
    agg = collections.defaultdict(lambda: [0, 0])
    for g, x in data:
        if x > threshold:
            agg[g][0] += x
            agg[g][1] += 1
    exp = [(g, v[0], v[1]) for g, v in sorted(agg.items())]
    got = [(g, int(sv), c) if not _isnan(sv) else None
           for g, sv, c in r.rows]
    assert got == exp
