"""Optional-``hypothesis`` shim.

Re-exports the real hypothesis API when the package is installed.  When it is
absent, exposes stand-ins so test modules still *collect* cleanly: strategy
expressions evaluate to inert placeholders and ``@given`` marks the test as
skipped instead of erroring at import time.
"""
import pytest

try:
    from hypothesis import HealthCheck, assume, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _Placeholder:
        """Absorbs any attribute access / call, so strategy expressions like
        ``st.lists(st.tuples(...), min_size=1)`` build without hypothesis."""

        def __init__(self, name="hypothesis-stub"):
            self._name = name

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return _Placeholder(f"{self._name}.{name}")

        def __repr__(self):
            return f"<{self._name}>"

    st = _Placeholder("st")
    HealthCheck = _Placeholder("HealthCheck")

    def assume(condition):
        return bool(condition)

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco


__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "assume", "given", "settings", "st"]
