"""Typed schema contract (repro.core.schema) + static schema-flow checker
(repro.analysis.schema_check, SCH001..SCH006) + runtime batch sanitizer.

Covers: ColumnType/Schema semantics, dtype-preservation end-to-end (float32
through scans, shuffles, aggregate folds and federated merges), UNION ALL
promotion parity with numpy, one seeded violation per SCH rule, the
``REPRO_CHECK_BATCHES`` exchange sanitizer, schema-carrying empty batches,
and ``schema:`` lines in EXPLAIN output.
"""
import numpy as np
import pytest

from repro.analysis.schema_check import (validate_dag_schemas,
                                         validate_plan_schema)
from repro.core.metastore import TableDesc
from repro.core.optimizer import plan as P
from repro.core.runtime.dag import MaterializedNode, TaskDAG, Vertex
from repro.core.runtime.exchange import Exchange, ExchangeConfig
from repro.core.runtime.vector import VectorBatch
from repro.core.schema import (ANY, FLOAT64, INT64, STR, ColumnType, Schema,
                               SchemaMismatchError, agg_result_type,
                               annotate_plan, infer_plan)
from repro.core.sql import ast as A


def _desc(name, cols):
    return TableDesc(name=name, schema=cols, partition_cols=[],
                     location="", props={})


def _scan(name, cols, alias=None):
    return P.Scan(_desc(name, cols), alias or name)


# ===========================================================================
# ColumnType / Schema semantics
# ===========================================================================
class TestColumnType:
    def test_sql_type_mapping(self):
        assert ColumnType.of_sql("BIGINT").token == "int64"
        assert ColumnType.of_sql("DOUBLE").token == "float64"
        assert ColumnType.of_sql("FLOAT").token == "float32"  # single prec.
        assert ColumnType.of_sql("STRING").token == "str"
        assert ColumnType.of_sql("BOOLEAN").token == "bool"
        assert ColumnType.of_sql("GEOMETRY").token == "any"  # unknown -> any

    def test_promotion_follows_numpy(self):
        assert INT64.promote(FLOAT64).token == "float64"
        f32 = ColumnType("float32")
        assert f32.promote(f32).token == "float32"
        assert INT64.promote(f32).token == "float64"  # numpy int64+float32
        assert ANY.promote(STR).token == "str"

    def test_str_numeric_promotion_is_a_contradiction(self):
        with pytest.raises(SchemaMismatchError):
            STR.promote(INT64)

    def test_accepts_nan_null_representation(self):
        # int64/bool columns travel as float64 once NULLs (NaN) pad them
        assert INT64.accepts(np.dtype(np.float64))
        assert not INT64.accepts(np.dtype("U8"))
        assert STR.accepts(np.dtype("U64"))
        assert ANY.accepts(np.dtype(np.float64))

    def test_agg_result_types(self):
        assert agg_result_type("count", STR).token == "int64"
        assert agg_result_type("sum", INT64).token == "int64"
        assert agg_result_type("avg", INT64).token == "float64"
        f32 = ColumnType("float32")
        assert agg_result_type("min", f32).token == "float32"
        assert agg_result_type("sum", f32).token == "float64"

    def test_schema_resolve_mirrors_lookup(self):
        s = Schema([("t.a", INT64), ("t.b", FLOAT64)])
        assert s.resolve("a").token == "int64"          # unique suffix
        assert s.resolve("t.a").token == "int64"        # exact
        assert s.resolve("b", table="t").token == "float64"
        from repro.core.schema import UnresolvedColumnError
        with pytest.raises(UnresolvedColumnError):
            s.resolve("zzz")

    def test_check_batch(self):
        s = Schema([("a", INT64), ("b", STR)])
        s.check_batch(VectorBatch({"a": np.arange(3),
                                   "b": np.array(["x", "y", "z"]),
                                   "__rowid__": np.arange(3)}))
        with pytest.raises(SchemaMismatchError, match="missing"):
            s.check_batch(VectorBatch({"a": np.arange(3)}))
        with pytest.raises(SchemaMismatchError, match="undeclared"):
            s.check_batch(VectorBatch({"a": np.arange(3),
                                       "b": np.array(["x", "y", "z"]),
                                       "extra": np.arange(3)}))


# ===========================================================================
# inference over plans
# ===========================================================================
class TestInference:
    def test_scan_types_from_catalog(self):
        sc = _scan("t", [("a", "BIGINT"), ("b", "DOUBLE"), ("c", "STRING")])
        s = infer_plan(sc)
        assert s.describe() == "t.a:int64, t.b:float64, t.c:str"

    def test_outer_join_nullable_padding(self):
        l = _scan("l", [("k", "BIGINT"), ("v", "BIGINT")])
        r = _scan("r", [("k", "BIGINT"), ("w", "BIGINT")])
        j = P.Join(l, r, "left", ["l.k"], ["r.k"])
        s = infer_plan(j)
        # padded right side widens to float64 (NaN-null), left unchanged
        assert s.get("l.v").token == "int64"
        assert s.get("r.w").token == "float64"
        assert s.get("r.w").nullable

    def test_union_promotes_positionally(self):
        a = _scan("a", [("x", "BIGINT")])
        b = _scan("b", [("y", "DOUBLE")])
        s = infer_plan(P.Union([a, b], all=True))
        assert s.names() == ["a.x"]
        assert s.get("a.x").token == "float64"


# ===========================================================================
# seeded violations: one per SCH rule
# ===========================================================================
class TestSeededViolations:
    def test_sch001_unresolved_column(self):
        sc = _scan("t", [("a", "BIGINT")])
        bad = P.Project(sc, [(A.Col("missing", "t"), "m")])
        findings = validate_plan_schema(bad)
        assert len(findings) == 1 and findings[0].startswith("SCH001")

    def test_sch002_union_branch_mismatch(self):
        a = _scan("a", [("x", "BIGINT")])
        b = _scan("b", [("y", "STRING")])  # str vs numeric: no promotion
        findings = validate_plan_schema(P.Union([a, b], all=True))
        assert len(findings) == 1 and findings[0].startswith("SCH002")

    def test_sch003_merge_fold_changes_state_dtype(self):
        # a float32 MIN partial re-folded through SUM (the shape a split /
        # collapse or federated-merge rewrite emits) widens the state
        mn = MaterializedNode(["g", "m"], "v2",
                              schema=Schema([("g", INT64),
                                             ("m", ColumnType("float32"))]))
        merge = P.Aggregate(mn, ["g"], [
            P.AggSpec("sum", A.Col("m"), False, "m")])
        findings = validate_plan_schema(merge)
        assert any(f.startswith("SCH003") for f in findings)
        # the correct merge fold (MIN partials re-MINed) is clean
        ok = P.Aggregate(mn, ["g"], [P.AggSpec("min", A.Col("m"), False, "m")])
        assert validate_plan_schema(ok) == []

    def test_sch004_join_key_family_mismatch(self):
        l = _scan("l", [("k", "STRING"), ("v", "BIGINT")])
        r = _scan("r", [("k", "BIGINT")])
        findings = validate_plan_schema(
            P.Join(l, r, "inner", ["l.k"], ["r.k"]))
        assert len(findings) == 1 and findings[0].startswith("SCH004")

    def test_sch005_residual_over_dropped_column(self):
        from repro.core.federation.datasource import ScanSpec

        desc = TableDesc(name="m.t", schema=[("a", "BIGINT"), ("b", "DOUBLE")],
                         partition_cols=[], location="", props={},
                         handler="memtable")
        fed = P.FederatedScan(desc, "t", ["a", "b"],
                              spec=ScanSpec(projection=["a"]),
                              output_cols=["t.a"])
        bad = P.Filter(fed, A.BinOp(">", A.Col("b", "t"), A.Lit(0)))
        findings = validate_plan_schema(bad)
        assert len(findings) == 1 and findings[0].startswith("SCH005")

    def test_sch006_placeholder_producer_disagreement(self):
        producer = _scan("t", [("a", "BIGINT"), ("b", "DOUBLE")])
        mn = MaterializedNode(["t.a", "t.zzz"], "v2")  # wrong column set
        dag = TaskDAG(vertices={
            "v2": Vertex("v2", producer),
            "v1": Vertex("v1", mn, deps=["v2"]),
        }, root="v1")
        findings = validate_dag_schemas(dag)
        assert len(findings) == 1 and findings[0].startswith("SCH006")

    def test_clean_dag_has_no_findings(self):
        producer = _scan("t", [("a", "BIGINT"), ("b", "DOUBLE")])
        mn = MaterializedNode(["t.a", "t.b"], "v2")
        dag = TaskDAG(vertices={
            "v2": Vertex("v2", producer),
            "v1": Vertex("v1", mn, deps=["v2"]),
        }, root="v1")
        assert validate_dag_schemas(dag) == []


# ===========================================================================
# runtime batch sanitizer (REPRO_CHECK_BATCHES / debug.check_batches)
# ===========================================================================
class TestBatchSanitizer:
    def test_put_rejects_nonconforming_morsel(self):
        cfg = ExchangeConfig({"debug.check_batches": True})
        ex = Exchange("v9", cfg)
        ex.declare_schema(Schema([("a", INT64), ("b", STR)]))
        ex.put(VectorBatch({"a": np.arange(2), "b": np.array(["x", "y"])}))
        with pytest.raises(SchemaMismatchError, match="exchange v9"):
            ex.put(VectorBatch({"a": np.arange(2)}))

    def test_sanitizer_off_means_no_verification(self, monkeypatch):
        monkeypatch.delenv("REPRO_CHECK_BATCHES", raising=False)
        cfg = ExchangeConfig({})
        ex = Exchange("v9", cfg)
        ex.declare_schema(Schema([("a", INT64)]))
        assert ex._verify is None  # put() pays one attribute test only
        ex.put(VectorBatch({"weird": np.arange(2)}))  # not checked

    def test_read_all_keeps_schema_on_empty(self):
        cfg = ExchangeConfig({})
        ex = Exchange("v1", cfg)
        ex.declare_schema(Schema([("a", INT64), ("b", STR)]))
        ex.close()
        out = ex.read_all()
        assert out.num_rows == 0
        assert out.column_names == ["a", "b"]
        assert out.cols["a"].dtype == np.int64


# ===========================================================================
# VectorBatch.concat schema preservation
# ===========================================================================
class TestConcat:
    def test_schemaless_placeholders_are_dropped(self):
        full = VectorBatch({"a": np.arange(3)})
        out = VectorBatch.concat([VectorBatch({}), full, VectorBatch({})])
        assert out.column_names == ["a"] and out.num_rows == 3

    def test_all_schemaless_stays_empty(self):
        out = VectorBatch.concat([VectorBatch({}), VectorBatch({})])
        assert out.num_rows == 0 and out.column_names == []

    def test_mismatch_names_the_edge(self):
        a = VectorBatch({"a": np.arange(2)})
        b = VectorBatch({"b": np.arange(2)})
        with pytest.raises(SchemaMismatchError, match="exchange v7"):
            VectorBatch.concat([a, b], context="exchange v7")


# ===========================================================================
# end-to-end: dtype preservation through the engine
# ===========================================================================
@pytest.fixture()
def session(warehouse):
    return warehouse.session()


class TestEndToEnd:
    def test_union_all_promotion_parity_with_numpy(self, session):
        session.execute("CREATE TABLE ints (x BIGINT)")
        session.execute("CREATE TABLE dbls (x DOUBLE)")
        session.execute("INSERT INTO ints VALUES (1), (2), (3)")
        session.execute("INSERT INTO dbls VALUES (0.5), (1.5)")
        r = session.execute(
            "SELECT x FROM ints UNION ALL SELECT x FROM dbls")
        col = r.batch.cols[r.batch.column_names[0]]
        want = np.promote_types(np.int64, np.float64)
        assert col.dtype == want
        assert sorted(col.tolist()) == [0.5, 1.0, 1.5, 2.0, 3.0]

    def test_float_column_is_single_precision(self, session):
        session.execute("CREATE TABLE f32 (k BIGINT, v FLOAT)")
        session.execute("INSERT INTO f32 VALUES (1, 1.5), (1, 2.5), (2, 0.25)")
        r = session.execute("SELECT v FROM f32")
        assert r.batch.cols[r.batch.column_names[0]].dtype == np.float32

    def test_float32_survives_min_max_group_by(self, session):
        session.execute("CREATE TABLE f32g (k BIGINT, v FLOAT)")
        session.execute(
            "INSERT INTO f32g VALUES (1, 1.5), (1, 2.5), (2, 0.25)")
        r = session.execute(
            "SELECT k, MIN(v) AS lo, MAX(v) AS hi FROM f32g GROUP BY k"
            " ORDER BY k")
        names = r.batch.column_names
        assert r.batch.cols[names[1]].dtype == np.float32
        assert r.batch.cols[names[2]].dtype == np.float32
        assert r.rows == [(1, 1.5, 2.5), (2, 0.25, 0.25)]

    def test_cast_as_float_is_single_precision(self, session):
        session.execute("CREATE TABLE c1 (x BIGINT)")
        session.execute("INSERT INTO c1 VALUES (1), (2)")
        r = session.execute("SELECT CAST(x AS FLOAT) AS f FROM c1")
        assert r.batch.cols[r.batch.column_names[0]].dtype == np.float32

    def test_float32_through_shuffled_group_by(self, warehouse):
        # force a partitioned shuffle so lanes + fold merges carry float32
        s = warehouse.session(**{"shuffle.partitions": 4})
        s.execute("CREATE TABLE big32 (k BIGINT, v FLOAT)")
        rows = ", ".join(f"({i % 13}, {i * 0.25})" for i in range(400))
        s.execute(f"INSERT INTO big32 VALUES {rows}")
        r = s.execute("SELECT k, MIN(v) AS lo FROM big32 GROUP BY k"
                      " ORDER BY k")
        assert r.batch.cols[r.batch.column_names[1]].dtype == np.float32
        lo = {k: v for k, v in r.rows}
        assert lo[0] == 0.0 and len(lo) == 13

    def test_float32_memtable_federated_min(self, warehouse):
        s = warehouse.session()
        s.execute("CREATE CATALOG m32 USING memtable")
        h = warehouse.catalogs.get("m32").handler
        h.load("t", VectorBatch({
            "g": np.arange(100) % 5,
            "v": (np.arange(100) * 0.5).astype(np.float32),
        }))
        assert dict(h.discover(None, "t"))["v"] == "FLOAT"  # f4 -> FLOAT
        r = s.execute("SELECT g, MIN(v) AS lo FROM m32.default.t GROUP BY g"
                      " ORDER BY g")
        assert r.batch.cols[r.batch.column_names[1]].dtype == np.float32
        assert r.rows[0] == (0, 0.0)

    def test_explain_carries_schema_lines(self, session):
        session.execute("CREATE TABLE e (a BIGINT, b DOUBLE)")
        out = session.explain("SELECT a, SUM(b) AS s FROM e GROUP BY a")
        assert "schema:" in out
        assert "s:float64?" in out

    def test_tolerant_annotation_never_raises(self):
        # annotate_plan degrades to schema=None on inference failures
        sc = _scan("t", [("a", "BIGINT")])
        bad = P.Project(sc, [(A.Col("missing", "t"), "m")])
        annotate_plan(bad)
        assert bad.schema is None
        assert sc.schema is not None
