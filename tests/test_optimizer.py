"""Optimizer behaviour: pushdown, pruning, join order, semijoin, shared work
(paper §4.1, §4.5, §4.6)."""
import numpy as np
import pytest

from repro.core.optimizer import plan as P
from repro.core.optimizer.rules import Optimizer, OptimizerConfig
from repro.core.optimizer.semijoin import insert_semijoin_reducers
from repro.core.optimizer.shared_work import find_shared_subplans
from repro.core.sql.binder import Binder
from repro.core.sql.parser import parse


def _optimized(wh, sql, **cfg):
    plan = Binder(wh.hms).bind(parse(sql))
    opt = Optimizer(wh.hms, OptimizerConfig(**cfg))
    return opt.optimize(plan), opt


def test_filter_pushdown_reaches_scan(star_schema):
    plan, _ = _optimized(
        star_schema,
        "SELECT ss_price FROM store_sales, item WHERE ss_item_sk = i_item_sk"
        " AND i_price > 50 AND ss_qty > 3",
    )
    scans = {s.alias: s for s in P.find_scans(plan)}
    assert scans["item"].pushed_filter is not None
    assert scans["store_sales"].pushed_filter is not None
    assert "i_price" in scans["item"].pushed_filter.key()


def test_cross_join_becomes_inner(star_schema):
    plan, _ = _optimized(
        star_schema,
        "SELECT ss_price FROM store_sales, item WHERE ss_item_sk = i_item_sk",
    )
    joins = [n for n in P.walk_plan(plan) if isinstance(n, P.Join)]
    assert joins and all(j.kind == "inner" and j.left_keys for j in joins)


def test_column_pruning_narrows_scan(star_schema):
    plan, _ = _optimized(star_schema, "SELECT SUM(ss_price) FROM store_sales")
    scan = P.find_scans(plan)[0]
    assert scan.columns == ["ss_price"]


def test_count_star_keeps_one_column(star_schema):
    plan, _ = _optimized(star_schema, "SELECT COUNT(*) FROM store_sales")
    scan = P.find_scans(plan)[0]
    assert len(scan.columns) == 1


def test_join_reorder_puts_selective_first(star_schema):
    plan, opt = _optimized(
        star_schema,
        "SELECT SUM(ss_price) FROM store_sales, item, date_dim"
        " WHERE ss_item_sk = i_item_sk AND ss_date_sk = d_date_sk"
        " AND i_category = 'Sports'",
    )
    joins = [n for n in P.walk_plan(plan) if isinstance(n, P.Join)]
    assert all(j.strategy in ("broadcast", "shuffle") for j in joins)
    # the build (right) side of every join must be the smaller side
    for j in joins:
        lr = opt.cost_model.estimate(j.left).rows
        rr = opt.cost_model.estimate(j.right).rows
        assert rr <= lr * 1.5


def test_transitive_inference_derives_filters(star_schema):
    plan, _ = _optimized(
        star_schema,
        "SELECT SUM(ss_price) FROM store_sales, item"
        " WHERE ss_item_sk = i_item_sk AND ss_item_sk = 7",
    )
    scans = {s.alias: s for s in P.find_scans(plan)}
    # filter on ss_item_sk must be propagated to item.i_item_sk
    assert scans["item"].pushed_filter is not None


def test_partition_pruning(tmp_path):
    from repro.core.session import Warehouse

    wh = Warehouse(str(tmp_path / "wh"))
    s = wh.session()
    s.execute("CREATE TABLE pt (v DOUBLE, d INT) PARTITIONED BY (d INT)")
    s.execute("INSERT INTO pt VALUES (1.0, 1), (2.0, 2), (3.0, 3)")
    plan, _ = _optimized(wh, "SELECT SUM(v) FROM pt WHERE d = 2")
    scan = P.find_scans(plan)[0]
    assert scan.partition_filter is not None
    r = s.execute("SELECT SUM(v) FROM pt WHERE d = 2")
    assert r.rows[0][0] == 2.0


def test_semijoin_reduction_inserted_and_correct(star_schema):
    plan, opt = _optimized(
        star_schema,
        "SELECT SUM(ss_price) FROM store_sales, item"
        " WHERE ss_item_sk = i_item_sk AND i_category = 'Sports'",
    )
    n = insert_semijoin_reducers(plan, opt.cost_model)
    assert n >= 1
    scans = {s.alias: s for s in P.find_scans(plan)}
    assert scans["store_sales"].runtime_filters
    # execution with reducers matches execution without
    s_on = star_schema.session(semijoin_reduction=True, result_cache=False)
    s_off = star_schema.session(semijoin_reduction=False, result_cache=False)
    sql = ("SELECT SUM(ss_price) FROM store_sales, item"
           " WHERE ss_item_sk = i_item_sk AND i_category = 'Sports'")
    assert abs(s_on.execute(sql).rows[0][0] - s_off.execute(sql).rows[0][0]) < 1e-6


def test_shared_work_detection(star_schema):
    sql = """SELECT a.c1, b.c2 FROM
      (SELECT i_category c1, COUNT(*) n FROM store_sales, item
       WHERE ss_item_sk = i_item_sk GROUP BY i_category) a,
      (SELECT i_category c2, SUM(ss_price) s FROM store_sales, item
       WHERE ss_item_sk = i_item_sk GROUP BY i_category) b
      WHERE a.c1 = b.c2"""
    plan, _ = _optimized(star_schema, sql)
    shared = find_shared_subplans(plan)
    assert shared  # the identical join subtree is detected once
    s = star_schema.session(result_cache=False)
    r = s.execute(sql)
    assert r.num_rows == 5
    assert r.info["shared_subplans"] >= 1


def test_cost_model_uses_hll_ndv(star_schema):
    from repro.core.optimizer.cost import CostModel

    cm = CostModel(star_schema.hms)
    stats = star_schema.hms.get_stats("item")
    assert 50 <= stats.columns["i_item_sk"].ndv <= 70  # HLL++ approximate
    plan = Binder(star_schema.hms).bind(
        parse("SELECT i_price FROM item WHERE i_item_sk = 3"))
    opt = Optimizer(star_schema.hms)
    plan = opt.optimize(plan)
    est = cm.estimate(plan)
    assert est.rows == pytest.approx(1.0, rel=1.0)  # 1/ndv selectivity
