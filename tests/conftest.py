import os
import signal
import tempfile
import threading

import numpy as np
import pytest

# NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here —
# smoke tests must see the real single CPU device.  Distributed tests spawn
# subprocesses that set --xla_force_host_platform_device_count themselves.


def pytest_addoption(parser):
    parser.addoption(
        "--test-timeout", type=float, default=0.0,
        help="per-test wall-clock limit in seconds (0 = disabled); a "
             "SIGALRM-based guard so a deadlocked scheduler fails the test "
             "instead of hanging the run (no pytest-timeout dependency)",
    )


@pytest.fixture(autouse=True)
def _per_test_alarm(request):
    """Fail (don't hang) any test that exceeds ``--test-timeout`` seconds.

    CPython delivers signals between bytecodes in the main thread, which
    interrupts pure-Python waits (locks, queues, Condition.wait) — exactly
    the states a deadlocked async scheduler would park a test in.
    """
    seconds = request.config.getoption("--test-timeout")
    if (
        not seconds
        or not hasattr(signal, "SIGALRM")
        or threading.current_thread() is not threading.main_thread()
    ):
        yield
        return

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"test exceeded --test-timeout={seconds}s (deadlock guard)"
        )

    old = signal.signal(signal.SIGALRM, _on_alarm)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(autouse=True)
def _validate_all_plans(monkeypatch):
    """Run the structural+schema DAG validator on every plan the suite
    compiles, and the runtime batch sanitizer on every exchange put.

    ``repro.analysis.plan_validator`` checks the validation flag per compile
    (not at import), so setting the env vars here covers warehouses created
    anywhere in a test — the whole tier-1 run doubles as validator and
    schema-contract coverage.
    """
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "1")
    monkeypatch.setenv("REPRO_CHECK_BATCHES", "1")


@pytest.fixture()
def warehouse(tmp_path):
    from repro.core.session import Warehouse

    return Warehouse(str(tmp_path / "wh"))


@pytest.fixture()
def session(warehouse):
    return warehouse.session()


@pytest.fixture()
def star_schema(warehouse):
    """Small star schema used across optimizer/MV/benchmark-style tests."""
    from repro.core.acid import AcidTable
    from repro.core.runtime.vector import VectorBatch

    s = warehouse.session()
    hms = warehouse.hms
    s.execute("CREATE TABLE date_dim (d_date_sk INT, d_year INT, d_moy INT)")
    s.execute("CREATE TABLE item (i_item_sk INT, i_category STRING, i_price DOUBLE)")
    s.execute(
        "CREATE TABLE store_sales (ss_item_sk INT, ss_date_sk INT,"
        " ss_customer_sk INT, ss_qty INT, ss_price DOUBLE)"
    )
    rng = np.random.default_rng(7)
    nd, ni, n = 36, 60, 8000
    tx = hms.open_txn()
    AcidTable(hms.get_table("date_dim"), hms).insert(tx, VectorBatch({
        "d_date_sk": np.arange(nd),
        "d_year": 2016 + np.arange(nd) // 12,
        "d_moy": np.arange(nd) % 12 + 1,
    }))
    AcidTable(hms.get_table("item"), hms).insert(tx, VectorBatch({
        "i_item_sk": np.arange(ni),
        "i_category": np.array(["Sports", "Books", "Home", "Toys", "Music"])[
            np.arange(ni) % 5],
        "i_price": rng.uniform(1, 100, ni).round(2),
    }))
    AcidTable(hms.get_table("store_sales"), hms).insert(tx, VectorBatch({
        "ss_item_sk": rng.integers(0, ni, n),
        "ss_date_sk": rng.integers(0, nd, n),
        "ss_customer_sk": rng.integers(0, 300, n),
        "ss_qty": rng.integers(1, 10, n),
        "ss_price": rng.uniform(1, 100, n).round(2),
    }))
    hms.commit_txn(tx)
    return warehouse
